//! **Algorithm 1** — mini-batch kernel k-means with the recursive distance
//! update rule (paper §4).
//!
//! The centers are never materialized. Instead the algorithm maintains, by
//! dynamic programming across iterations,
//!
//! * `px[x][j] = ⟨φ(x), C_j⟩` for **all** `x ∈ X` — updated via
//!   `⟨φ(x), C'_j⟩ = (1−α)⟨φ(x), C_j⟩ + α⟨φ(x), cm(B^j)⟩`, and
//! * `cc[j] = ⟨C_j, C_j⟩` — updated via the expanded square.
//!
//! Each iteration costs `O(n(b+k))`: `n·b` kernel evaluations for the new
//! cross terms plus `n·k` bookkeeping — already far below the full-batch
//! `O(n²)`, but still linear in `n` (the truncated Algorithm 2 removes even
//! that).

use super::backend::argmin_rows;
use super::init::choose_centers;
use super::learning_rate::{LearningRate, RateState};
use super::{FitResult, Init};
use crate::kernels::KernelProvider;
use crate::util::parallel::{par_rows_mut, par_rows_mut3};
use crate::util::rng::Rng;
use crate::util::timing::{Profiler, Stopwatch};

/// Configuration for [`MiniBatchKernelKMeans`] (Algorithm 1).
#[derive(Clone, Debug)]
pub struct MiniBatchConfig {
    /// Number of clusters.
    pub k: usize,
    /// Batch size `b` (sampled uniformly with repetitions).
    pub batch_size: usize,
    /// Iteration budget.
    pub max_iters: usize,
    /// Early-stopping threshold ε on batch improvement
    /// `f_{B_i}(C_i) − f_{B_i}(C_{i+1})`; `None` runs `max_iters` fixed
    /// iterations (the paper's experimental protocol).
    pub epsilon: Option<f64>,
    /// Learning-rate schedule for the center updates.
    pub learning_rate: LearningRate,
    /// Center initialization method.
    pub init: Init,
    /// Optional per-point weights (weighted variant, footnote 1).
    pub weights: Option<Vec<f64>>,
}

impl Default for MiniBatchConfig {
    fn default() -> Self {
        MiniBatchConfig {
            k: 2,
            batch_size: 1024,
            max_iters: 200,
            epsilon: None,
            learning_rate: LearningRate::Beta,
            init: Init::default(),
            weights: None,
        }
    }
}

/// Algorithm 1 runner.
pub struct MiniBatchKernelKMeans {
    cfg: MiniBatchConfig,
}

impl MiniBatchKernelKMeans {
    /// Wrap a configuration.
    pub fn new(cfg: MiniBatchConfig) -> Self {
        MiniBatchKernelKMeans { cfg }
    }

    /// Run Algorithm 1 over the gram.
    pub fn fit(&self, gram: &dyn KernelProvider, rng: &mut Rng) -> FitResult {
        let n = gram.n();
        let k = self.cfg.k;
        let b = self.cfg.batch_size.min(n.max(1));
        assert!(k >= 1 && k <= n);
        let mut prof = Profiler::new();
        let weights = self.cfg.weights.as_deref();

        // ---- init: centers are single points --------------------------------
        let sw = Stopwatch::start();
        let seeds = choose_centers(gram, k, self.cfg.init, rng);
        // px[x*k + j] = ⟨φ(x), C_j⟩ ; cc[j] = ⟨C_j, C_j⟩.
        let mut px = vec![0.0f64; n * k];
        {
            let seeds = &seeds;
            par_rows_mut(&mut px, k, |row0, block| {
                for (r, row) in block.chunks_mut(k).enumerate() {
                    let x = row0 + r;
                    for (j, &s) in seeds.iter().enumerate() {
                        row[j] = gram.eval(x, s);
                    }
                }
            });
        }
        let mut cc: Vec<f64> = seeds.iter().map(|&s| gram.self_k(s)).collect();
        prof.add("init", sw.secs());

        let mut rate = RateState::new(self.cfg.learning_rate, k);
        let mut history = Vec::new();
        let mut iterations = 0;
        let mut converged = false;
        // Maintained by the fused update+argmin pass: the assignment and min
        // squared distance of *every* dataset point under the current
        // centers. Each iteration's DP sweep already touches every px row,
        // so the argmin rides along for free and the final assignment pass
        // disappears (§Perf, DESIGN.md §5).
        let mut assign_all = vec![0usize; n];
        let mut mins_all = vec![0.0f64; n];
        let mut have_assignment = false;

        for _iter in 0..self.cfg.max_iters {
            iterations += 1;
            // ---- sample batch & assign -------------------------------------
            let sw = Stopwatch::start();
            let batch = rng.sample_with_replacement(n, b);
            let mut batch_dist = vec![0.0f64; b * k];
            for (r, &x) in batch.iter().enumerate() {
                let kxx = gram.self_k(x);
                for j in 0..k {
                    batch_dist[r * k + j] = (kxx - 2.0 * px[x * k + j] + cc[j]).max(0.0);
                }
            }
            let (assign, mins) = argmin_rows(&batch_dist, k);
            let f_before = super::objective::weighted_mean(&batch, &mins, weights);
            history.push(f_before);
            prof.add("assign", sw.secs());

            // ---- per-cluster batch members & learning rates ------------------
            let sw = Stopwatch::start();
            let mut members: Vec<Vec<usize>> = vec![Vec::new(); k];
            for (r, &j) in assign.iter().enumerate() {
                members[j].push(batch[r]);
            }
            let alphas: Vec<f64> = (0..k)
                .map(|j| rate.alpha(j, members[j].len(), b))
                .collect();
            // Weighted masses of each batch cluster (for weighted cm).
            let mass: Vec<f64> = members
                .iter()
                .map(|m| match weights {
                    None => m.len() as f64,
                    Some(w) => m.iter().map(|&x| w[x]).sum(),
                })
                .collect();

            // ⟨C_j, cm(B^j)⟩ from *old* px — O(b).
            let c_dot_cm: Vec<f64> = (0..k)
                .map(|j| {
                    if members[j].is_empty() {
                        return 0.0;
                    }
                    let mut s = 0.0;
                    for &y in &members[j] {
                        let wy = weights.map(|w| w[y]).unwrap_or(1.0);
                        s += wy * px[y * k + j];
                    }
                    s / mass[j]
                })
                .collect();
            // ⟨cm(B^j), cm(B^j)⟩ — O(Σ b_j²) ≤ O(b²).
            let cm_dot_cm: Vec<f64> = (0..k)
                .map(|j| {
                    if members[j].is_empty() {
                        return 0.0;
                    }
                    let pts = &members[j];
                    let mut s = 0.0;
                    for (a, &y) in pts.iter().enumerate() {
                        let wy = weights.map(|w| w[y]).unwrap_or(1.0);
                        s += wy * wy * gram.self_k(y);
                        for &z in pts.iter().skip(a + 1) {
                            let wz = weights.map(|w| w[z]).unwrap_or(1.0);
                            s += 2.0 * wy * wz * gram.eval(y, z);
                        }
                    }
                    s / (mass[j] * mass[j])
                })
                .collect();
            prof.add("moments", sw.secs());

            // ---- DP update fused with the argmin pass ------------------------
            // cc's recursion needs only the O(b) moments above, so it updates
            // *first*; the px sweep then reads the new cc and emits each
            // point's distance-argmin in the same cache-warm visit — every
            // row of the DP tables is touched exactly once per iteration.
            let sw = Stopwatch::start();
            for j in 0..k {
                let a = alphas[j];
                if a == 0.0 {
                    continue;
                }
                cc[j] = (1.0 - a) * (1.0 - a) * cc[j]
                    + 2.0 * a * (1.0 - a) * c_dot_cm[j]
                    + a * a * cm_dot_cm[j];
            }
            // Concatenated member columns (center j owns mranges[j]): lets
            // the non-materialized branch gather each row's kernel values
            // in one planned-gather call — on the streaming provider that
            // amortizes cache lookups over whole tiles instead of paying
            // two locks per value, and the grouping/sort is hoisted into
            // the plan once per iteration, not once per point.
            let mut mcols: Vec<u32> = Vec::with_capacity(b);
            let mut mranges: Vec<(usize, usize)> = Vec::with_capacity(k);
            for mjs in members.iter() {
                let start = mcols.len();
                mcols.extend(mjs.iter().map(|&y| y as u32));
                mranges.push((start, mcols.len()));
            }
            let plan = gram.plan_gather(&mcols);
            {
                let members = &members;
                let alphas = &alphas;
                let mass = &mass;
                let cc = &cc;
                let mcols = &mcols;
                let mranges = &mranges;
                let plan = &plan;
                par_rows_mut3(
                    &mut px,
                    k,
                    &mut assign_all,
                    1,
                    &mut mins_all,
                    1,
                    |row0, block, ab, mb| {
                        let mut gathered = vec![0.0f64; mcols.len()];
                        for (r, row) in block.chunks_mut(k).enumerate() {
                            let x = row0 + r;
                            // Hoist the gram row once per point (§Perf):
                            // direct f32 loads beat per-element enum
                            // dispatch ~3x.
                            let grow = gram.row_slice(x);
                            if grow.is_none() {
                                gram.row_gather_planned(x, plan, &mut gathered);
                            }
                            for j in 0..k {
                                let a = alphas[j];
                                if a == 0.0 {
                                    continue;
                                }
                                let (s, e) = mranges[j];
                                let mut cross = 0.0;
                                // Per-center reduction in member order — the
                                // same accumulation order in every branch
                                // (bit-identity across providers).
                                match (grow, weights) {
                                    (Some(g), None) => {
                                        for &y in &members[j] {
                                            cross += g[y] as f64;
                                        }
                                    }
                                    (Some(g), Some(w)) => {
                                        for &y in &members[j] {
                                            cross += w[y] * g[y] as f64;
                                        }
                                    }
                                    (None, None) => {
                                        for &v in &gathered[s..e] {
                                            cross += v;
                                        }
                                    }
                                    (None, Some(w)) => {
                                        for (&c, &v) in
                                            mcols[s..e].iter().zip(&gathered[s..e])
                                        {
                                            cross += w[c as usize] * v;
                                        }
                                    }
                                }
                                row[j] = (1.0 - a) * row[j] + a * cross / mass[j];
                            }
                            // Fused argmin over the freshly-updated row.
                            let kxx = gram.self_k(x);
                            let mut best = 0usize;
                            let mut bestv = f64::INFINITY;
                            for (j, &pxj) in row.iter().enumerate() {
                                let d = (kxx - 2.0 * pxj + cc[j]).max(0.0);
                                if d < bestv {
                                    best = j;
                                    bestv = d;
                                }
                            }
                            ab[r] = best;
                            mb[r] = bestv;
                        }
                    },
                );
            }
            have_assignment = true;
            prof.add("update", sw.secs());

            // ---- early stopping on the same batch ---------------------------
            // The fused pass already computed every point's post-update min
            // distance; the batch objective is a gather.
            if let Some(eps) = self.cfg.epsilon {
                let sw = Stopwatch::start();
                let mins_after: Vec<f64> = batch.iter().map(|&x| mins_all[x]).collect();
                let f_after = super::objective::weighted_mean(&batch, &mins_after, weights);
                prof.add("stopping", sw.secs());
                if f_before - f_after < eps {
                    converged = true;
                    break;
                }
            }
        }

        // ---- finalize: the fused pass left assignments/mins for all points --
        let sw = Stopwatch::start();
        if !have_assignment {
            // max_iters = 0: no fused sweep ran; assign from the init tables.
            for x in 0..n {
                let kxx = gram.self_k(x);
                let mut best = 0usize;
                let mut bestv = f64::INFINITY;
                for j in 0..k {
                    let d = (kxx - 2.0 * px[x * k + j] + cc[j]).max(0.0);
                    if d < bestv {
                        best = j;
                        bestv = d;
                    }
                }
                assign_all[x] = best;
                mins_all[x] = bestv;
            }
        }
        let points: Vec<usize> = (0..n).collect();
        let objective = super::objective::weighted_mean(&points, &mins_all, weights);
        prof.add("finalize", sw.secs());

        FitResult {
            assignments: assign_all,
            objective,
            history,
            iterations,
            converged,
            profiler: prof,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};
    use crate::kernels::{Gram, KernelFunction};
    use crate::metrics::ari;

    fn fixture(n: usize) -> crate::data::Dataset {
        let mut rng = Rng::seeded(7);
        blobs(
            &SyntheticSpec::new(n, 4, 3).with_std(0.4).with_separation(7.0),
            &mut rng,
        )
    }

    #[test]
    fn recovers_blobs_with_beta_rate() {
        let ds = fixture(600);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 20.0 });
        let cfg = MiniBatchConfig { k: 3, batch_size: 128, max_iters: 60, ..Default::default() };
        let mut rng = Rng::seeded(1);
        let res = MiniBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
        let score = ari(ds.labels.as_ref().unwrap(), &res.assignments);
        assert!(score > 0.9, "ARI={score}");
    }

    #[test]
    fn recovers_blobs_with_sklearn_rate() {
        let ds = fixture(600);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 20.0 });
        let cfg = MiniBatchConfig {
            k: 3,
            batch_size: 128,
            max_iters: 60,
            learning_rate: LearningRate::Sklearn,
            ..Default::default()
        };
        let mut rng = Rng::seeded(2);
        let res = MiniBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
        let score = ari(ds.labels.as_ref().unwrap(), &res.assignments);
        assert!(score > 0.9, "ARI={score}");
    }

    #[test]
    fn early_stopping_fires_on_converged_data() {
        let ds = fixture(400);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 20.0 });
        let cfg = MiniBatchConfig {
            k: 3,
            batch_size: 200,
            max_iters: 200,
            epsilon: Some(1e-3),
            ..Default::default()
        };
        let mut rng = Rng::seeded(3);
        let res = MiniBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
        assert!(res.converged, "should stop early; ran {}", res.iterations);
        assert!(res.iterations < 200);
    }

    #[test]
    fn px_cc_invariants_vs_bruteforce_window() {
        // Cross-check Algorithm 1's DP tables against an explicit
        // CenterWindow fed the same update stream.
        use crate::kkmeans::state::CenterWindow;
        let ds = fixture(120);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 10.0 });
        let n = ds.n;
        let k = 2;
        let b = 16;
        let seeds = [3usize, 77];
        let mut px = vec![0.0f64; n * k];
        for x in 0..n {
            for (j, &s) in seeds.iter().enumerate() {
                px[x * k + j] = gram.eval(x, s);
            }
        }
        let mut cc: Vec<f64> = seeds.iter().map(|&s| gram.self_k(s)).collect();
        let mut windows: Vec<CenterWindow> =
            seeds.iter().map(|&s| CenterWindow::new(s, usize::MAX)).collect();
        let mut rng = Rng::seeded(5);
        for _ in 0..10 {
            let batch = rng.sample_with_replacement(n, b);
            // Assign by px/cc.
            let mut members: Vec<Vec<usize>> = vec![Vec::new(); k];
            for &x in &batch {
                let mut best = 0;
                let mut bestv = f64::INFINITY;
                for j in 0..k {
                    let d = gram.self_k(x) - 2.0 * px[x * k + j] + cc[j];
                    if d < bestv {
                        best = j;
                        bestv = d;
                    }
                }
                members[best].push(x);
            }
            for j in 0..k {
                let bj = members[j].len();
                if bj == 0 {
                    continue;
                }
                let a = (bj as f64 / b as f64).sqrt();
                // DP update.
                let mut c_dot_cm = 0.0;
                for &y in &members[j] {
                    c_dot_cm += px[y * k + j];
                }
                c_dot_cm /= bj as f64;
                let mut cm2 = 0.0;
                for &y in &members[j] {
                    for &z in &members[j] {
                        cm2 += gram.eval(y, z);
                    }
                }
                cm2 /= (bj * bj) as f64;
                for x in 0..n {
                    let mut cross = 0.0;
                    for &y in &members[j] {
                        cross += gram.eval(x, y);
                    }
                    px[x * k + j] = (1.0 - a) * px[x * k + j] + a * cross / bj as f64;
                }
                cc[j] = (1.0 - a) * (1.0 - a) * cc[j]
                    + 2.0 * a * (1.0 - a) * c_dot_cm
                    + a * a * cm2;
                windows[j].apply_update(a, &members[j], None);
            }
        }
        // Compare against the explicit representation.
        for j in 0..k {
            let cc_win = windows[j].self_inner(&gram);
            assert!((cc[j] - cc_win).abs() < 1e-8, "cc[{j}]: {} vs {cc_win}", cc[j]);
            for x in (0..n).step_by(13) {
                let px_win = windows[j].cross_with_point(&gram, x);
                assert!(
                    (px[x * k + j] - px_win).abs() < 1e-8,
                    "px[{x},{j}]: {} vs {px_win}",
                    px[x * k + j]
                );
            }
        }
    }

    #[test]
    fn history_has_one_entry_per_iteration() {
        let ds = fixture(200);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 10.0 });
        let cfg = MiniBatchConfig { k: 3, batch_size: 64, max_iters: 17, ..Default::default() };
        let mut rng = Rng::seeded(6);
        let res = MiniBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
        assert_eq!(res.iterations, 17);
        assert_eq!(res.history.len(), 17);
        assert!(!res.converged);
    }
}
