//! The assignment-step backend abstraction.
//!
//! One iteration of Algorithm 2 needs the `b × k` matrix of squared
//! feature-space distances between batch points and truncated centers:
//!
//! `Δ(x, Ĉ^j) = K(x,x) − 2·Σ_m w_{jm} K(x, s_{jm}) + ⟨Ĉ^j, Ĉ^j⟩`.
//!
//! This is the `Õ(kb²)` compute hot-spot, so it is pluggable:
//!
//! * [`NativeBackend`] — pure Rust, parallel over batch rows. Always
//!   available, works with any [`KernelProvider`] (on-the-fly,
//!   materialized, or the streaming tile-LRU-cached provider).
//! * [`crate::runtime::XlaBackend`] — executes the AOT-compiled JAX/Pallas
//!   graph (Layer 1/2) through PJRT; available for feature kernels when a
//!   matching artifact was built by `make artifacts`.
//!
//! Backends must agree numerically (integration tests cross-check them).

use super::state::CenterWindow;
use crate::kernels::KernelProvider;

/// Computes batch-to-center squared distances for Algorithm 2.
///
/// The two distance methods are mutually defaulted — an implementation
/// must override at least one of them. Hot loops call
/// [`AssignBackend::distances_into`] with a buffer hoisted out of the
/// iteration loop, so a fit performs no per-iteration distance-matrix
/// allocations on backends that override it.
pub trait AssignBackend {
    /// Returns the row-major `batch.len() × centers.len()` distance matrix.
    /// Distances are squared, clamped at 0 against floating-point rounding.
    fn distances(
        &mut self,
        gram: &dyn KernelProvider,
        batch: &[usize],
        centers: &mut [CenterWindow],
    ) -> Vec<f64> {
        let mut out = Vec::new();
        self.distances_into(gram, batch, centers, &mut out);
        out
    }

    /// [`AssignBackend::distances`] into a caller-owned buffer (resized to
    /// `batch.len() × centers.len()`), reusing its capacity across calls.
    fn distances_into(
        &mut self,
        gram: &dyn KernelProvider,
        batch: &[usize],
        centers: &mut [CenterWindow],
        out: &mut Vec<f64>,
    ) {
        *out = self.distances(gram, batch, centers);
    }

    /// Backend label for reports.
    fn name(&self) -> &'static str;
}

/// Pure-Rust reference backend.
///
/// Gathers every center's support once into one concatenated
/// structure-of-arrays buffer, caches `⟨Ĉ,Ĉ⟩` in the window, and runs the
/// cross-term contraction `K(B, S)·w` through the provider's engine
/// ([`KernelProvider::weighted_cross_into`]): parallel over batch rows
/// (pool-dispatched, no per-call thread spawns), with kernel values
/// produced by the panel micro-kernels against cached row norms and tiled
/// over support columns so each packed tile stays cache-resident across
/// the whole batch chunk (DESIGN.md §5 and §7).
#[derive(Debug, Default, Clone)]
pub struct NativeBackend;

impl AssignBackend for NativeBackend {
    fn distances_into(
        &mut self,
        gram: &dyn KernelProvider,
        batch: &[usize],
        centers: &mut [CenterWindow],
        out: &mut Vec<f64>,
    ) {
        let k = centers.len();
        let b = batch.len();
        // ⟨Ĉ_j, Ĉ_j⟩ (cached inside the window between calls; O(1) when
        // updates flow through apply_update_cc).
        let cc: Vec<f64> = centers.iter_mut().map(|c| c.self_inner(gram)).collect();
        // Concatenated supports: center j owns sup_idx[ranges[j].0..ranges[j].1].
        let total: usize = centers.iter().map(|c| c.support_len()).sum();
        let mut sup_idx: Vec<u32> = Vec::with_capacity(total);
        let mut sup_w: Vec<f64> = Vec::with_capacity(total);
        let mut ranges: Vec<(usize, usize)> = Vec::with_capacity(k);
        for c in centers.iter() {
            let start = sup_idx.len();
            for (y, w) in c.support() {
                sup_idx.push(y as u32);
                sup_w.push(w);
            }
            ranges.push((start, sup_idx.len()));
        }
        // out[r·k + j] = Σ_m w_m·K(x_r, s_m), then finished into distances
        // in place: Δ = K(x,x) − 2·cross + ⟨Ĉ,Ĉ⟩, clamped at 0.
        out.clear();
        out.resize(b * k, 0.0);
        gram.weighted_cross_into(batch, &sup_idx, &sup_w, &ranges, out);
        for (r, &x) in batch.iter().enumerate() {
            let kxx = gram.self_k(x);
            let row = &mut out[r * k..(r + 1) * k];
            for (v, &ccj) in row.iter_mut().zip(cc.iter()) {
                *v = (kxx - 2.0 * *v + ccj).max(0.0);
            }
        }
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Row-wise argmin over a `b × k` distance matrix → (assignment, min dist).
pub fn argmin_rows(dist: &[f64], k: usize) -> (Vec<usize>, Vec<f64>) {
    let mut assign = Vec::new();
    let mut mins = Vec::new();
    argmin_rows_into(dist, k, &mut assign, &mut mins);
    (assign, mins)
}

/// [`argmin_rows`] into caller-owned buffers (cleared, then filled) —
/// the per-iteration form, reusing capacity across a fit's iterations.
pub fn argmin_rows_into(
    dist: &[f64],
    k: usize,
    assign: &mut Vec<usize>,
    mins: &mut Vec<f64>,
) {
    assert!(k >= 1 && dist.len() % k == 0);
    let b = dist.len() / k;
    assign.clear();
    mins.clear();
    assign.reserve(b);
    mins.reserve(b);
    for r in 0..b {
        let row = &dist[r * k..(r + 1) * k];
        let mut best = 0usize;
        let mut bestv = row[0];
        for (j, &v) in row.iter().enumerate().skip(1) {
            if v < bestv {
                best = j;
                bestv = v;
            }
        }
        assign.push(best);
        mins.push(bestv);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};
    use crate::kernels::{Gram, KernelFunction};
    use crate::util::rng::Rng;

    #[test]
    fn native_distances_match_bruteforce() {
        let mut rng = Rng::seeded(99);
        let ds = blobs(&SyntheticSpec::new(150, 3, 3), &mut rng);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 5.0 });
        let mut centers: Vec<CenterWindow> =
            (0..3).map(|j| CenterWindow::new(j * 10, 40)).collect();
        for c in centers.iter_mut() {
            let pts: Vec<usize> = (0..8).map(|_| rng.below(ds.n)).collect();
            c.apply_update(0.6, &pts, None);
        }
        let batch: Vec<usize> = (0..20).map(|_| rng.below(ds.n)).collect();
        let mut backend = NativeBackend;
        let dist = backend.distances(&gram, &batch, &mut centers);
        assert_eq!(dist.len(), 20 * 3);
        for (r, &x) in batch.iter().enumerate() {
            for (j, c) in centers.iter_mut().enumerate() {
                let cross = c.cross_with_point(&gram, x);
                let want = (gram.self_k(x) - 2.0 * cross + c.self_inner(&gram)).max(0.0);
                assert!(
                    (dist[r * 3 + j] - want).abs() < 1e-10,
                    "r={r} j={j}: {} vs {want}",
                    dist[r * 3 + j]
                );
            }
        }
    }

    #[test]
    fn distance_to_own_init_point_is_zero() {
        let mut rng = Rng::seeded(3);
        let ds = blobs(&SyntheticSpec::new(50, 2, 2), &mut rng);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 4.0 });
        let mut centers = vec![CenterWindow::new(7, 10)];
        let mut backend = NativeBackend;
        let dist = backend.distances(&gram, &[7], &mut centers);
        assert!(dist[0].abs() < 1e-12);
    }

    #[test]
    fn argmin_rows_basics() {
        let dist = vec![3.0, 1.0, 2.0, /* row 2 */ 0.5, 4.0, 0.5];
        let (assign, mins) = argmin_rows(&dist, 3);
        assert_eq!(assign, vec![1, 0]); // ties break to the lower index
        assert_eq!(mins, vec![1.0, 0.5]);
    }
}
