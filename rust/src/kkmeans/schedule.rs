//! Batch schedules: how many points each iteration samples, and which.
//!
//! The paper's experimental protocol uses a fixed batch size `b`. Nested
//! (geometric-growth) schedules, in the spirit of Newling & Fleuret's
//! nested mini-batch k-means (arXiv:1602.02934), instead start from a
//! small `b₀` and grow the batch by a factor `g ≥ 1` each iteration,
//! *reusing* the previous batch as a deterministic prefix of the next:
//! early iterations are cheap and noisy, late iterations approach the
//! full-batch gradient. Reuse is nearly free under the lazy
//! generation-stamped assignment state ([`super::state::LazyAssignState`]):
//! a carried point was refreshed last iteration, so its replay suffix is a
//! single iteration's update-log entries.
//!
//! The contract pinned by `rust/tests/prop_schedule.rs`: a
//! [`NestedSchedule`] with growth factor exactly 1 draws the identical
//! index sequence from the identical RNG stream as [`FixedSchedule`], so a
//! growth-1 nested fit is **bit-identical** to a fixed-b fit — same
//! assignments, same objective bits, same RNG position afterwards.

use crate::util::rng::Rng;

/// A policy deciding each iteration's batch.
///
/// Implementations fill `batch` with indices in `[0, n)`; the fit loops in
/// [`super::minibatch`] / [`super::truncated`] treat `batch.len()` as the
/// iteration's effective `b` (learning rates, objective means, and the
/// O(b²) moments all use it).
pub trait BatchSchedule {
    /// Fill `batch` for `iteration` (0-based). Must be deterministic in
    /// `(self state, iteration, n, rng stream)`.
    fn next_batch(&mut self, iteration: usize, n: usize, rng: &mut Rng, batch: &mut Vec<usize>);

    /// Largest batch this schedule can ever produce for a dataset of `n`
    /// points — used to pre-reserve iteration buffers.
    fn max_batch(&self, n: usize) -> usize;

    /// Short name for labels and reports.
    fn name(&self) -> &'static str;

    /// Restore internal carry state from a training checkpoint
    /// (DESIGN.md §12). `prev` is the batch of the last completed
    /// iteration; stateless schedules ignore it. A resumed
    /// [`NestedSchedule`] carries the same prefix the uninterrupted run
    /// would, keeping resumed fits bit-identical.
    fn restore_prev(&mut self, _prev: &[usize]) {}
}

/// The paper's protocol: every iteration samples exactly `b` indices
/// uniformly with repetitions.
#[derive(Clone, Debug)]
pub struct FixedSchedule {
    b: usize,
}

impl FixedSchedule {
    /// Fixed batch size `b` (clamped to `n` at sampling time).
    pub fn new(b: usize) -> Self {
        FixedSchedule { b }
    }
}

impl BatchSchedule for FixedSchedule {
    fn next_batch(&mut self, _iteration: usize, n: usize, rng: &mut Rng, batch: &mut Vec<usize>) {
        let b = self.b.min(n.max(1)).max(1);
        rng.sample_with_replacement_into(n, b, batch);
    }

    fn max_batch(&self, n: usize) -> usize {
        self.b.min(n.max(1)).max(1)
    }

    fn name(&self) -> &'static str {
        "fixed"
    }
}

/// Geometric growth with deterministic sample reuse.
///
/// Iteration `i` targets `⌈b₀·gⁱ⌉` points (clamped to `[b₀, n]`). The
/// batch is assembled as `fresh ++ carried`: `carried` is a prefix of the
/// *previous* batch (up to `target − b₀` points), and `fresh = target −
/// carried` new draws from the RNG. Two consequences:
///
/// * `g = 1` ⇒ `target = b₀`, `carried = 0`: the schedule makes exactly
///   the same `sample_with_replacement_into(n, b₀, ·)` call as
///   [`FixedSchedule`] — bit-identical fits, pinned by property test.
/// * `g = 2` ⇒ the whole previous batch is carried and an equal number of
///   fresh points joins it — true nesting `B₀ ⊂ B₁ ⊂ …` (as multisets).
#[derive(Clone, Debug)]
pub struct NestedSchedule {
    b0: usize,
    growth: f64,
    prev: Vec<usize>,
}

impl NestedSchedule {
    /// Start from `b0` and grow by `growth ≥ 1` per iteration.
    pub fn new(b0: usize, growth: f64) -> Self {
        assert!(
            growth >= 1.0 && growth.is_finite(),
            "nested growth factor must be a finite value ≥ 1, got {growth}"
        );
        NestedSchedule { b0, growth, prev: Vec::new() }
    }

    fn target(&self, iteration: usize, n: usize) -> usize {
        let cap = n.max(1);
        let b0 = self.b0.min(cap).max(1);
        let t = b0 as f64 * self.growth.powi(iteration.min(i32::MAX as usize) as i32);
        if !t.is_finite() || t >= cap as f64 {
            cap
        } else {
            (t.ceil() as usize).clamp(b0, cap)
        }
    }
}

impl BatchSchedule for NestedSchedule {
    fn next_batch(&mut self, iteration: usize, n: usize, rng: &mut Rng, batch: &mut Vec<usize>) {
        let cap = n.max(1);
        let b0 = self.b0.min(cap).max(1);
        let target = self.target(iteration, n);
        // Carry at most target − b₀ points so at least b₀ fresh draws
        // happen every iteration (and none of the RNG stream is skipped
        // relative to the fixed schedule when growth = 1).
        let carry = (target - b0).min(self.prev.len());
        let fresh = target - carry;
        rng.sample_with_replacement_into(n, fresh, batch);
        batch.extend_from_slice(&self.prev[..carry]);
        self.prev.clear();
        self.prev.extend_from_slice(batch);
    }

    fn max_batch(&self, n: usize) -> usize {
        if self.growth > 1.0 {
            n.max(1)
        } else {
            self.b0.min(n.max(1)).max(1)
        }
    }

    fn name(&self) -> &'static str {
        "nested"
    }

    fn restore_prev(&mut self, prev: &[usize]) {
        self.prev.clear();
        self.prev.extend_from_slice(prev);
    }
}

/// Declarative schedule choice — what configs, CLI flags, and experiment
/// specs carry; [`ScheduleSpec::build`] instantiates the stateful policy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScheduleSpec {
    /// Fixed batch size (the paper's protocol).
    Fixed,
    /// Geometric growth from the configured batch size with the given
    /// per-iteration factor (≥ 1).
    Nested {
        /// Per-iteration growth factor `g ≥ 1`.
        growth: f64,
    },
}

impl Default for ScheduleSpec {
    fn default() -> Self {
        ScheduleSpec::Fixed
    }
}

impl ScheduleSpec {
    /// Parse a `--schedule` CLI value (`fixed` | `nested`), with `growth`
    /// supplying the nested factor.
    pub fn from_name(name: &str, growth: f64) -> ScheduleSpec {
        match name {
            "fixed" => ScheduleSpec::Fixed,
            "nested" => ScheduleSpec::Nested { growth },
            other => panic!("unknown schedule {other:?} (known: fixed, nested)"),
        }
    }

    /// Instantiate the stateful policy for a base batch size.
    pub fn build(&self, batch_size: usize) -> Box<dyn BatchSchedule> {
        match *self {
            ScheduleSpec::Fixed => Box::new(FixedSchedule::new(batch_size)),
            ScheduleSpec::Nested { growth } => Box::new(NestedSchedule::new(batch_size, growth)),
        }
    }

    /// Short label for run names and report rows, e.g. `fixed` or
    /// `nested(g=2)`.
    pub fn label(&self) -> String {
        match *self {
            ScheduleSpec::Fixed => "fixed".into(),
            ScheduleSpec::Nested { growth } => format!("nested(g={growth})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn draws(sched: &mut dyn BatchSchedule, n: usize, iters: usize, seed: u64) -> Vec<Vec<usize>> {
        let mut rng = Rng::seeded(seed);
        let mut out = Vec::new();
        let mut batch = Vec::new();
        for i in 0..iters {
            sched.next_batch(i, n, &mut rng, &mut batch);
            out.push(batch.clone());
        }
        out
    }

    #[test]
    fn growth_one_matches_fixed_and_rng_position() {
        let (n, b, iters, seed) = (500usize, 32usize, 12usize, 9u64);
        let mut fixed = FixedSchedule::new(b);
        let mut nested = NestedSchedule::new(b, 1.0);
        let mut rf = Rng::seeded(seed);
        let mut rn = Rng::seeded(seed);
        let mut bf = Vec::new();
        let mut bn = Vec::new();
        for i in 0..iters {
            fixed.next_batch(i, n, &mut rf, &mut bf);
            nested.next_batch(i, n, &mut rn, &mut bn);
            assert_eq!(bf, bn, "iteration {i} diverged");
        }
        // Identical RNG stream position afterwards.
        assert_eq!(rf.next_u64(), rn.next_u64());
    }

    #[test]
    fn growth_two_doubles_and_nests() {
        let n = 10_000;
        let batches = draws(&mut NestedSchedule::new(16, 2.0), n, 6, 3);
        for (i, b) in batches.iter().enumerate() {
            assert_eq!(b.len(), (16usize << i).min(n), "iteration {i}");
        }
        // The previous batch is carried verbatim as the suffix.
        for w in batches.windows(2) {
            let (prev, next) = (&w[0], &w[1]);
            assert_eq!(&next[next.len() - prev.len()..], prev.as_slice());
        }
    }

    #[test]
    fn nested_clamps_at_n() {
        let n = 100;
        let batches = draws(&mut NestedSchedule::new(64, 2.0), n, 5, 1);
        assert_eq!(batches[0].len(), 64);
        for b in &batches[1..] {
            assert_eq!(b.len(), n);
        }
        assert!(batches.iter().flatten().all(|&x| x < n));
    }

    #[test]
    fn fractional_growth_is_monotone_and_bounded() {
        let n = 5_000;
        let batches = draws(&mut NestedSchedule::new(100, 1.3), n, 10, 7);
        let mut last = 0;
        for b in &batches {
            assert!(b.len() >= last);
            assert!(b.len() <= n);
            last = b.len();
        }
        assert_eq!(batches[0].len(), 100);
        assert_eq!(batches[1].len(), 130);
    }

    #[test]
    fn huge_iteration_count_saturates_to_n() {
        let mut s = NestedSchedule::new(8, 2.0);
        assert_eq!(s.target(500, 1000), 1000);
        let mut rng = Rng::seeded(2);
        let mut batch = Vec::new();
        s.next_batch(500, 1000, &mut rng, &mut batch);
        assert_eq!(batch.len(), 1000);
    }

    #[test]
    fn spec_roundtrip_and_labels() {
        assert_eq!(ScheduleSpec::from_name("fixed", 2.0), ScheduleSpec::Fixed);
        assert_eq!(
            ScheduleSpec::from_name("nested", 1.5),
            ScheduleSpec::Nested { growth: 1.5 }
        );
        assert_eq!(ScheduleSpec::default(), ScheduleSpec::Fixed);
        assert_eq!(ScheduleSpec::Fixed.label(), "fixed");
        assert_eq!(ScheduleSpec::Nested { growth: 2.0 }.label(), "nested(g=2)");
        assert_eq!(ScheduleSpec::Fixed.build(64).name(), "fixed");
        assert_eq!(ScheduleSpec::Nested { growth: 2.0 }.build(64).name(), "nested");
    }

    #[test]
    #[should_panic(expected = "growth factor")]
    fn growth_below_one_rejected() {
        NestedSchedule::new(32, 0.5);
    }

    #[test]
    fn restore_prev_resumes_bit_identically() {
        // A schedule rebuilt mid-sequence from restore_prev + a restored
        // RNG draws the exact batches the uninterrupted schedule would —
        // the property training-checkpoint resume (DESIGN.md §12) rests on.
        let (n, b, seed) = (500usize, 16usize, 21u64);
        let mut full = NestedSchedule::new(b, 2.0);
        let mut rf = Rng::seeded(seed);
        let mut buf = Vec::new();
        let mut batches = Vec::new();
        let mut mid_state = None;
        for i in 0..6 {
            if i == 3 {
                mid_state = Some(rf.state());
            }
            full.next_batch(i, n, &mut rf, &mut buf);
            batches.push(buf.clone());
        }
        let (words, cache) = mid_state.unwrap();
        let mut resumed = NestedSchedule::new(b, 2.0);
        resumed.restore_prev(&batches[2]);
        let mut rr = Rng::from_state(words, cache);
        for (i, want) in batches.iter().enumerate().skip(3) {
            resumed.next_batch(i, n, &mut rr, &mut buf);
            assert_eq!(&buf, want, "iteration {i} diverged after resume");
        }
    }
}
