//! Learning-rate policies for the mini-batch center update
//! `C_{i+1}^j = (1−α_i^j)·C_i^j + α_i^j·cm(B_i^j)`.
//!
//! * **β rate** (Schwartzman 2023): `α = √(b_j/b)` — does *not* decay to 0
//!   over time. Theorem 1's termination guarantee and Lemma 3's truncation
//!   bound both rely on this rate (it decays old contributions
//!   exponentially). The paper's `β`-prefixed algorithms use it.
//! * **sklearn rate** (Sculley 2010 / sklearn's `MiniBatchKMeans`):
//!   `α = b_j / c_j` where `c_j` is the cumulative count of points ever
//!   assigned to center j. Goes to 0 as `1/i`, so old contributions decay
//!   only polynomially — the reason truncation interacts poorly with it
//!   (paper §6 Discussion).

/// Which learning-rate schedule to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LearningRate {
    /// `α = √(b_j / b)` — Schwartzman (2023). Non-vanishing.
    Beta,
    /// `α = b_j / cumulative_count_j` — sklearn. Vanishing.
    Sklearn,
}

impl LearningRate {
    /// Short display name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            LearningRate::Beta => "beta",
            LearningRate::Sklearn => "sklearn",
        }
    }
}

/// Per-run mutable state for a learning-rate schedule (the sklearn rate
/// tracks cumulative per-center counts).
#[derive(Clone, Debug)]
pub struct RateState {
    kind: LearningRate,
    /// Cumulative counts per center (sklearn only; seeded at 1 per sklearn's
    /// own convention so the first batch doesn't fully overwrite init).
    counts: Vec<f64>,
}

impl RateState {
    /// Fresh state for `k` centers.
    pub fn new(kind: LearningRate, k: usize) -> RateState {
        RateState { kind, counts: vec![1.0; k] }
    }

    /// α for center `j` receiving `b_j` batch points out of a batch of `b`.
    /// Always in [0, 1]; exactly 0 when `b_j = 0` (center unchanged).
    pub fn alpha(&mut self, j: usize, b_j: usize, b: usize) -> f64 {
        debug_assert!(b_j <= b);
        if b_j == 0 {
            return 0.0;
        }
        match self.kind {
            LearningRate::Beta => (b_j as f64 / b as f64).sqrt(),
            LearningRate::Sklearn => {
                self.counts[j] += b_j as f64;
                b_j as f64 / self.counts[j]
            }
        }
    }

    /// Which schedule this state drives.
    pub fn kind(&self) -> LearningRate {
        self.kind
    }

    /// Cumulative per-center counts, exported for the `serve::format`
    /// stream checkpoint (the sklearn rate's only mutable state).
    pub(crate) fn counts(&self) -> &[f64] {
        &self.counts
    }

    /// Rebuild from checkpointed parts — the inverse of
    /// [`RateState::counts`] for a known schedule kind.
    pub(crate) fn from_parts(kind: LearningRate, counts: Vec<f64>) -> RateState {
        RateState { kind, counts }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn beta_rate_formula() {
        let mut r = RateState::new(LearningRate::Beta, 3);
        assert_eq!(r.alpha(0, 0, 100), 0.0);
        assert!((r.alpha(0, 25, 100) - 0.5).abs() < 1e-12);
        assert!((r.alpha(1, 100, 100) - 1.0).abs() < 1e-12);
        // Stateless: same inputs, same output across iterations.
        assert!((r.alpha(0, 25, 100) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn sklearn_rate_decays() {
        let mut r = RateState::new(LearningRate::Sklearn, 1);
        let a1 = r.alpha(0, 10, 32);
        let a2 = r.alpha(0, 10, 32);
        let a3 = r.alpha(0, 10, 32);
        assert!(a1 > a2 && a2 > a3, "{a1} {a2} {a3}");
        // a_i = 10 / (1 + 10·i)
        assert!((a1 - 10.0 / 11.0).abs() < 1e-12);
        assert!((a2 - 10.0 / 21.0).abs() < 1e-12);
    }

    #[test]
    fn sklearn_counts_are_per_center() {
        let mut r = RateState::new(LearningRate::Sklearn, 2);
        let _ = r.alpha(0, 50, 64);
        let b = r.alpha(1, 50, 64); // center 1 untouched so far
        assert!((b - 50.0 / 51.0).abs() < 1e-12);
    }

    #[test]
    fn alpha_bounded() {
        let mut beta = RateState::new(LearningRate::Beta, 1);
        let mut skl = RateState::new(LearningRate::Sklearn, 1);
        for bj in [0usize, 1, 7, 32] {
            for state in [&mut beta, &mut skl] {
                let a = state.alpha(0, bj, 32);
                assert!((0.0..=1.0).contains(&a));
            }
        }
    }
}
