//! Center initialization in feature space.
//!
//! Kernel k-means++ (Arthur & Vassilvitskii 2007, run through the kernel):
//! the first center is uniform; each subsequent center is a dataset point
//! sampled with probability proportional to its squared feature-space
//! distance to the nearest chosen center:
//!
//! `Δ(x, y) = K(x,x) − 2K(x,y) + K(y,y)`.
//!
//! Initial centers are single dataset points — trivially convex combinations
//! of X, as Algorithms 1 and 2 require — and carry the `O(log k)` expected
//! approximation guarantee used by Theorem 1(3).

use super::Init;
use crate::kernels::KernelProvider;
use crate::util::rng::Rng;

/// Choose `k` initial center *point indices* according to `method`.
pub fn choose_centers(
    gram: &dyn KernelProvider,
    k: usize,
    method: Init,
    rng: &mut Rng,
) -> Vec<usize> {
    let n = gram.n();
    assert!(k >= 1 && k <= n, "k={k} out of range for n={n}");
    match method {
        Init::Uniform => rng.sample_without_replacement(n, k),
        Init::KMeansPlusPlus => kmeanspp(gram, (0..n).collect(), k, rng),
        Init::KMeansPlusPlusOnSample(m) => {
            let m = m.clamp(k, n);
            let sample = rng.sample_without_replacement(n, m);
            kmeanspp(gram, sample, k, rng)
        }
    }
}

/// Kernel k-means++ D² sampling over a candidate index set.
/// Cost: O(|candidates| · k) kernel evaluations. The per-center distance
/// sweep gathers `K(candidates, center)` through the provider's block
/// engine — parallel over candidates, served by the panel micro-kernels
/// (with their cached-norm distance expansion) on feature kernels, and
/// tile-grouped on the streaming provider — with values identical to
/// per-element [`feature_sqdist`].
fn kmeanspp(
    gram: &dyn KernelProvider,
    candidates: Vec<usize>,
    k: usize,
    rng: &mut Rng,
) -> Vec<usize> {
    let m = candidates.len();
    assert!(k <= m);
    let mut centers = Vec::with_capacity(k);
    let first = candidates[rng.below(m)];
    centers.push(first);
    let mut col = vec![0.0f64; m];
    gram.block_into(&candidates, &[first], &mut col);
    // min squared distance of each candidate to the chosen centers
    let mut min_d2: Vec<f64> = candidates
        .iter()
        .zip(col.iter())
        .map(|(&x, &kxy)| sqdist_from_cross(gram, x, first, kxy))
        .collect();
    while centers.len() < k {
        let next_pos = rng.weighted_choice(&min_d2);
        let next = candidates[next_pos];
        // Degenerate case (all remaining distances 0): weighted_choice fell
        // back to uniform, which may repeat a chosen point; nudge forward.
        let next = if centers.contains(&next) {
            match candidates.iter().find(|c| !centers.contains(c)) {
                Some(&c) => c,
                None => next, // all points identical; duplicates are fine
            }
        } else {
            next
        };
        centers.push(next);
        gram.block_into(&candidates, &[next], &mut col);
        for (pos, &x) in candidates.iter().enumerate() {
            let d2 = sqdist_from_cross(gram, x, next, col[pos]);
            if d2 < min_d2[pos] {
                min_d2[pos] = d2;
            }
        }
    }
    centers
}

/// `‖φ(x) − φ(y)‖²` given an already-gathered cross term `kxy = K(x, y)`
/// (clamped at 0 against rounding) — must stay arithmetically identical to
/// [`feature_sqdist`].
#[inline]
fn sqdist_from_cross(gram: &dyn KernelProvider, x: usize, y: usize, kxy: f64) -> f64 {
    (gram.self_k(x) - 2.0 * kxy + gram.self_k(y)).max(0.0)
}

/// `‖φ(x) − φ(y)‖²` via kernel evaluations (clamped at 0 against rounding).
#[inline]
pub fn feature_sqdist(gram: &dyn KernelProvider, x: usize, y: usize) -> f64 {
    (gram.self_k(x) - 2.0 * gram.eval(x, y) + gram.self_k(y)).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};
    use crate::kernels::{Gram, KernelFunction};
    use crate::util::rng::Rng;

    fn fixture() -> crate::data::Dataset {
        let mut rng = Rng::seeded(77);
        blobs(
            &SyntheticSpec::new(300, 4, 3).with_std(0.3).with_separation(8.0),
            &mut rng,
        )
    }

    #[test]
    fn uniform_yields_distinct_valid_indices() {
        let ds = fixture();
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 8.0 });
        let mut rng = Rng::seeded(1);
        let c = choose_centers(&gram, 5, Init::Uniform, &mut rng);
        assert_eq!(c.len(), 5);
        let set: std::collections::HashSet<_> = c.iter().collect();
        assert_eq!(set.len(), 5);
        assert!(c.iter().all(|&i| i < ds.n));
    }

    #[test]
    fn kmeanspp_hits_every_separated_blob() {
        // With well-separated blobs, D² sampling should pick one center per
        // blob essentially always.
        let ds = fixture();
        let labels = ds.labels.clone().unwrap();
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 50.0 });
        let mut hits = 0;
        let trials = 20;
        for seed in 0..trials {
            let mut rng = Rng::seeded(seed);
            let c = choose_centers(&gram, 3, Init::KMeansPlusPlus, &mut rng);
            let blobs_hit: std::collections::HashSet<_> =
                c.iter().map(|&i| labels[i]).collect();
            if blobs_hit.len() == 3 {
                hits += 1;
            }
        }
        assert!(hits >= trials * 3 / 4, "kmeans++ covered all blobs only {hits}/{trials}");
    }

    #[test]
    fn uniform_misses_blobs_sometimes_kmeanspp_wins() {
        // Sanity: uniform init should cover all 3 blobs noticeably less often
        // than k-means++ (it's the reason ++ exists).
        let ds = fixture();
        let labels = ds.labels.clone().unwrap();
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 50.0 });
        let mut uniform_hits = 0;
        for seed in 100..160 {
            let mut rng = Rng::seeded(seed);
            let c = choose_centers(&gram, 3, Init::Uniform, &mut rng);
            let blobs_hit: std::collections::HashSet<_> =
                c.iter().map(|&i| labels[i]).collect();
            if blobs_hit.len() == 3 {
                uniform_hits += 1;
            }
        }
        assert!(uniform_hits < 60, "uniform init suspiciously perfect");
    }

    #[test]
    fn sample_variant_stays_within_bounds() {
        let ds = fixture();
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 8.0 });
        let mut rng = Rng::seeded(5);
        let c = choose_centers(&gram, 4, Init::KMeansPlusPlusOnSample(50), &mut rng);
        assert_eq!(c.len(), 4);
        assert!(c.iter().all(|&i| i < ds.n));
    }

    #[test]
    fn feature_sqdist_zero_on_self_positive_off() {
        let ds = fixture();
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 8.0 });
        assert_eq!(feature_sqdist(&gram, 3, 3), 0.0);
        assert!(feature_sqdist(&gram, 0, 200) > 0.0);
    }

    #[test]
    fn identical_points_degenerate_ok() {
        let ds = crate::data::Dataset::new("dup", vec![1.0f32; 20], 10, 2);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 1.0 });
        let mut rng = Rng::seeded(9);
        let c = choose_centers(&gram, 3, Init::KMeansPlusPlus, &mut rng);
        assert_eq!(c.len(), 3);
    }
}
