//! Sliding-window truncated center state (paper §4.1).
//!
//! Each center is implicitly `Ĉ^j = Σ_{(y,w) ∈ window} w·φ(y)`: a sparse
//! convex-ish combination of recent batch points. After the update
//! `C_{i+1} = (1−α_i)C_i + α_i·cm(B_i^j)`, the contribution of the batch
//! from iteration ℓ carries coefficient `α_ℓ · Π_{z=ℓ+1..i}(1−α_z)`.
//!
//! The *efficient sliding-window implementation* the paper's footnote 4
//! alludes to: instead of rescaling every stored coefficient by `(1−α)`
//! each iteration (O(window) work), we keep per-entry **raw** coefficients
//! and a single global `scale`; effective coefficient = raw × scale. An
//! update multiplies `scale` by `(1−α)` and inserts the new entry with
//! `raw = α/(b_j·scale)` — O(b_j) per update. Underflow is handled by
//! folding `scale` back into the raws when it gets tiny.
//!
//! Truncation (the `Q_i^j` set): the window keeps the minimal suffix of
//! batches whose point count reaches τ, so the support size is at most
//! τ + b. While the window still reaches back to iteration 1, the decayed
//! initial center `C_1^j·Π(1−α)` is retained so `Ĉ = C` exactly
//! (Equation 1's second case); the first trim drops it.
//!
//! This module also owns [`LazyAssignState`] — Algorithm 1's lazy,
//! generation-stamped `⟨φ(x), C_j⟩` table (DESIGN.md §9). Where
//! [`CenterWindow`] represents a center *explicitly* (support points ×
//! coefficients), the lazy state keeps the center update *log* and
//! reconstructs any point's `px[x][j]` on demand by replaying exactly the
//! recursion steps the removed eager sweep would have applied — so the
//! replay is bit-identical to the eager dynamic program while an iteration
//! touches only the b sampled points.

use crate::kernels::{GatherPlan, KernelProvider};
use crate::util::parallel::{par_dynamic, par_rows_mut3, SharedSlice};
use std::sync::Mutex;

/// One iteration's surviving contribution: the batch-cluster points and
/// their raw per-point coefficients.
#[derive(Clone, Debug)]
struct WindowEntry {
    points: Vec<u32>,
    /// Raw per-point coefficients (effective = raw × window.scale).
    raws: Vec<f64>,
}

impl WindowEntry {
    fn len(&self) -> usize {
        self.points.len()
    }
}

/// Borrowed view of one window's complete internal state, read by the
/// versioned checkpoint writer (`serve::format`, kind `stream`) with zero
/// copying of the support data. The owned inverse for loading is
/// [`WindowState`] → [`CenterWindow::from_state`].
pub(crate) struct WindowView<'a> {
    /// `(points, raw coefficients)` per surviving entry, oldest first.
    pub entries: Vec<(&'a [u32], &'a [f64])>,
    /// Global decay multiplier (effective coefficient = raw × scale).
    pub scale: f64,
    /// The decayed initial center, while the window still reaches
    /// iteration 1.
    pub init_point: Option<(u32, f64)>,
    /// Maintained ⟨Ĉ,Ĉ⟩, if valid at snapshot time.
    pub cc_cache: Option<f64>,
    /// Incremental-cc drift counter (schedules the next exact refresh).
    pub updates_since_exact: u32,
}

/// One window's complete internal state, owned — what the checkpoint
/// loader rebuilds and hands to [`CenterWindow::from_state`] for a
/// bit-for-bit restore.
#[derive(Clone, Debug)]
pub(crate) struct WindowState {
    /// `(points, raw coefficients)` per surviving entry, oldest first.
    pub entries: Vec<(Vec<u32>, Vec<f64>)>,
    /// Global decay multiplier (effective coefficient = raw × scale).
    pub scale: f64,
    /// The decayed initial center, while the window still reaches
    /// iteration 1.
    pub init_point: Option<(u32, f64)>,
    /// Truncation parameter τ.
    pub tau: usize,
    /// Maintained ⟨Ĉ,Ĉ⟩, if valid at snapshot time.
    pub cc_cache: Option<f64>,
    /// Incremental-cc drift counter (schedules the next exact refresh).
    pub updates_since_exact: u32,
}

/// The truncated representation of one center.
#[derive(Clone, Debug)]
pub struct CenterWindow {
    entries: std::collections::VecDeque<WindowEntry>,
    /// Global decay multiplier (see module docs).
    scale: f64,
    /// The initial center `C_1^j` (a single dataset point) with its raw
    /// coefficient; present while the window still reaches iteration 1.
    init_point: Option<(u32, f64)>,
    /// Truncation parameter τ (`usize::MAX` = never truncate ⇒ Algorithm 1
    /// semantics with an explicit representation).
    tau: usize,
    /// Total number of points across entries.
    total_points: usize,
    /// Cached ⟨Ĉ, Ĉ⟩; invalidated on update (or maintained incrementally by
    /// [`CenterWindow::apply_update_cc`]).
    cc_cache: Option<f64>,
    /// Updates since the last exact ⟨Ĉ,Ĉ⟩ recomputation (drift control for
    /// the incremental path).
    updates_since_exact: u32,
}

/// Recompute ⟨Ĉ,Ĉ⟩ exactly after this many incremental updates (bounds
/// floating-point drift; the O(M²) cost amortizes to nothing).
pub const CC_REFRESH_PERIOD: u32 = 256;

impl CenterWindow {
    /// A fresh center at dataset point `init_idx`.
    pub fn new(init_idx: usize, tau: usize) -> CenterWindow {
        assert!(tau >= 1);
        CenterWindow {
            entries: std::collections::VecDeque::new(),
            scale: 1.0,
            init_point: Some((init_idx as u32, 1.0)),
            tau,
            total_points: 0,
            cc_cache: None,
            updates_since_exact: 0,
        }
    }

    /// τ from Lemma 3: `⌈b·ln²(28γ/ε)⌉` guarantees `‖Ĉ−C‖ ≤ ε/28`.
    pub fn lemma3_tau(b: usize, gamma: f64, epsilon: f64) -> usize {
        let l = (28.0 * gamma / epsilon).ln().max(1.0);
        (b as f64 * l * l).ceil() as usize
    }

    /// Apply the mini-batch update with learning rate `alpha` and the batch
    /// points assigned to this center. `point_weights`, when given, are the
    /// (positive) dataset weights of those points — the weighted-variant
    /// `cm` is the weighted mean.
    pub fn apply_update(
        &mut self,
        alpha: f64,
        points: &[usize],
        point_weights: Option<&[f64]>,
    ) {
        assert!((0.0..=1.0).contains(&alpha), "alpha={alpha}");
        if alpha == 0.0 || points.is_empty() {
            return; // b_j = 0 ⇒ center unchanged
        }
        self.cc_cache = None;
        if alpha >= 1.0 {
            // Old center's coefficient is exactly 0: drop all history.
            self.entries.clear();
            self.init_point = None;
            self.total_points = 0;
            self.scale = 1.0;
        } else {
            self.scale *= 1.0 - alpha;
            if self.scale < 1e-150 {
                self.renormalize();
            }
        }
        // cm(B_i^j) per-point coefficients (sum to 1), scaled by α.
        let raws: Vec<f64> = match point_weights {
            None => {
                let c = alpha / (points.len() as f64 * self.scale);
                vec![c; points.len()]
            }
            Some(ws) => {
                assert_eq!(ws.len(), points.len());
                let total: f64 = ws.iter().sum();
                ws.iter()
                    .map(|w| alpha * w / (total * self.scale))
                    .collect()
            }
        };
        self.entries.push_back(WindowEntry {
            points: points.iter().map(|&p| p as u32).collect(),
            raws,
        });
        self.total_points += points.len();
        // Trim to the minimal suffix with ≥ τ points (the Q_i^j rule).
        while let Some(front) = self.entries.front() {
            let without_front = self.total_points - front.len();
            if without_front >= self.tau {
                self.total_points = without_front;
                self.entries.pop_front();
                // History no longer reaches iteration 1.
                self.init_point = None;
            } else {
                break;
            }
        }
    }

    fn renormalize(&mut self) {
        let s = self.scale;
        for e in self.entries.iter_mut() {
            for r in e.raws.iter_mut() {
                *r *= s;
            }
        }
        if let Some((_, r)) = self.init_point.as_mut() {
            *r *= s;
        }
        self.scale = 1.0;
    }

    /// Support size: number of (point, coefficient) pairs representing Ĉ.
    pub fn support_len(&self) -> usize {
        self.total_points + usize::from(self.init_point.is_some())
    }

    /// Iterate the support as (dataset index, effective coefficient).
    pub fn support(&self) -> impl Iterator<Item = (usize, f64)> + '_ {
        self.init_point
            .iter()
            .map(move |&(idx, raw)| (idx as usize, raw * self.scale))
            .chain(self.entries.iter().flat_map(move |e| {
                e.points
                    .iter()
                    .zip(e.raws.iter())
                    .map(move |(&p, &r)| (p as usize, r * self.scale))
            }))
    }

    /// Σ of effective coefficients. Equals 1 exactly while untruncated
    /// (convex combination); drops below 1 once history is discarded.
    pub fn weight_sum(&self) -> f64 {
        self.support().map(|(_, w)| w).sum()
    }

    /// Whether this window still represents the exact (untruncated) center.
    pub fn is_exact(&self) -> bool {
        self.init_point.is_some()
    }

    /// `⟨φ(x), Ĉ⟩` — O(support) kernel evaluations. Takes the materialized
    /// fast path (direct row loads) when available.
    pub fn cross_with_point(&self, gram: &dyn KernelProvider, x: usize) -> f64 {
        if let Some(row) = gram.row_slice(x) {
            self.support().map(|(y, w)| w * row[y] as f64).sum()
        } else {
            self.support().map(|(y, w)| w * gram.eval(x, y)).sum()
        }
    }

    /// `⟨Ĉ, Ĉ⟩` — O(support²) kernel evaluations, cached until the next
    /// update (the two backend calls per iteration share it). When updates
    /// flow through [`CenterWindow::apply_update_cc`] the cache is
    /// maintained *incrementally* and this is O(1).
    pub fn self_inner(&mut self, gram: &dyn KernelProvider) -> f64 {
        if let Some(cc) = self.cc_cache {
            return cc;
        }
        let sup: Vec<(usize, f64)> = self.support().collect();
        let mut cc = 0.0;
        for (a, &(ya, wa)) in sup.iter().enumerate() {
            if let Some(row) = gram.row_slice(ya) {
                cc += wa * wa * row[ya] as f64;
                for &(yb, wb) in sup.iter().skip(a + 1) {
                    cc += 2.0 * wa * wb * row[yb] as f64;
                }
            } else {
                cc += wa * wa * gram.self_k(ya);
                for &(yb, wb) in sup.iter().skip(a + 1) {
                    cc += 2.0 * wa * wb * gram.eval(ya, yb);
                }
            }
        }
        self.cc_cache = Some(cc);
        self.updates_since_exact = 0;
        cc
    }

    /// Like [`CenterWindow::apply_update`], but maintains `⟨Ĉ,Ĉ⟩`
    /// incrementally instead of invalidating it: the update rule expands to
    ///
    /// `cc' = (1−α)²·cc + 2α(1−α)·⟨Ĉ, cm⟩ + α²·⟨cm, cm⟩`,
    ///
    /// costing `O(M·b_j + b_j²)` instead of the `O(M²)` recomputation the
    /// next `self_inner` would pay — the dominant saving of the §Perf pass
    /// (EXPERIMENTS.md). Trimmed window entries are subtracted via
    /// `‖Ĉ−e‖² = cc − 2⟨e,Ĉ⟩ + ‖e‖²`. Every [`CC_REFRESH_PERIOD`] updates
    /// the cache is recomputed exactly to bound drift.
    pub fn apply_update_cc(
        &mut self,
        alpha: f64,
        points: &[usize],
        point_weights: Option<&[f64]>,
        gram: &dyn KernelProvider,
    ) {
        assert!((0.0..=1.0).contains(&alpha), "alpha={alpha}");
        if alpha == 0.0 || points.is_empty() {
            return;
        }
        self.updates_since_exact += 1;
        let track = self.updates_since_exact < CC_REFRESH_PERIOD;

        // cm(B) per-point coefficients u (sum to 1).
        let u: Vec<f64> = match point_weights {
            None => vec![1.0 / points.len() as f64; points.len()],
            Some(ws) => {
                let total: f64 = ws.iter().sum();
                ws.iter().map(|w| w / total).collect()
            }
        };

        if track {
            let cc = self.self_inner(gram);
            // ⟨Ĉ, cm⟩ — O(M·b_j).
            let mut c_dot_cm = 0.0;
            for (up, &p) in u.iter().zip(points.iter()) {
                c_dot_cm += up * self.cross_with_point(gram, p);
            }
            // ⟨cm, cm⟩ — O(b_j²).
            let mut cm_dot_cm = 0.0;
            for (ui, &p) in u.iter().zip(points.iter()) {
                if let Some(row) = gram.row_slice(p) {
                    for (uq, &q) in u.iter().zip(points.iter()) {
                        cm_dot_cm += ui * uq * row[q] as f64;
                    }
                } else {
                    for (uq, &q) in u.iter().zip(points.iter()) {
                        cm_dot_cm += ui * uq * gram.eval(p, q);
                    }
                }
            }
            let new_cc = if alpha >= 1.0 {
                cm_dot_cm
            } else {
                (1.0 - alpha) * (1.0 - alpha) * cc
                    + 2.0 * alpha * (1.0 - alpha) * c_dot_cm
                    + alpha * alpha * cm_dot_cm
            };
            self.cc_cache = Some(new_cc.max(0.0));
        } else {
            self.cc_cache = None;
        }

        // ---- state update (mirrors apply_update, trim-aware) ---------------
        if alpha >= 1.0 {
            self.entries.clear();
            self.init_point = None;
            self.total_points = 0;
            self.scale = 1.0;
        } else {
            self.scale *= 1.0 - alpha;
            if self.scale < 1e-150 {
                self.renormalize();
            }
        }
        let raws: Vec<f64> = u.iter().map(|up| alpha * up / self.scale).collect();
        self.entries.push_back(WindowEntry {
            points: points.iter().map(|&p| p as u32).collect(),
            raws,
        });
        self.total_points += points.len();

        let mut popped_any = false;
        while let Some(front) = self.entries.front() {
            let without_front = self.total_points - front.len();
            if without_front < self.tau {
                break;
            }
            if track {
                // Subtract entry e from cc *before* removing it.
                let e_pts: Vec<usize> =
                    front.points.iter().map(|&p| p as usize).collect();
                let e_ws: Vec<f64> = front.raws.iter().map(|&r| r * self.scale).collect();
                self.subtract_from_cc(gram, &e_pts, &e_ws);
            }
            self.total_points = without_front;
            self.entries.pop_front();
            popped_any = true;
        }
        if popped_any {
            if let Some((idx, raw)) = self.init_point {
                if track {
                    self.subtract_from_cc(gram, &[idx as usize], &[raw * self.scale]);
                }
                self.init_point = None;
            }
        }
        if self.cc_cache.is_none() {
            // Refresh period hit: recompute exactly now (O(M²), amortized).
            let _ = self.self_inner(gram);
        }
    }

    /// Rebuild this window with dataset indices translated through `remap`
    /// (used by the streaming reservoir's compaction). Entry structure,
    /// coefficients, and the cc cache are preserved; unmapped indices panic
    /// (compaction must keep every referenced row).
    pub fn remap_indices(
        &self,
        remap: &std::collections::HashMap<usize, usize>,
        tau: usize,
    ) -> CenterWindow {
        let map = |p: u32| -> u32 {
            *remap
                .get(&(p as usize))
                .unwrap_or_else(|| panic!("compaction dropped referenced row {p}"))
                as u32
        };
        CenterWindow {
            entries: self
                .entries
                .iter()
                .map(|e| WindowEntry {
                    points: e.points.iter().map(|&p| map(p)).collect(),
                    raws: e.raws.clone(),
                })
                .collect(),
            scale: self.scale,
            init_point: self.init_point.map(|(p, r)| (map(p), r)),
            tau,
            total_points: self.total_points,
            cc_cache: self.cc_cache,
            updates_since_exact: self.updates_since_exact,
        }
    }

    /// Borrow the complete internal state for the `serve::format` stream
    /// checkpoint (kind `stream`). Everything a bit-for-bit resume needs is
    /// exposed: entry structure with *raw* coefficients, the global decay
    /// `scale`, the retained initial center, the maintained ⟨Ĉ,Ĉ⟩ cache,
    /// and the drift counter that schedules the next exact recomputation —
    /// without cloning the O(τ+b) support arrays (only a small vector of
    /// slice pairs is allocated).
    pub(crate) fn state_view(&self) -> WindowView<'_> {
        WindowView {
            entries: self
                .entries
                .iter()
                .map(|e| (e.points.as_slice(), e.raws.as_slice()))
                .collect(),
            scale: self.scale,
            init_point: self.init_point,
            cc_cache: self.cc_cache,
            updates_since_exact: self.updates_since_exact,
        }
    }

    /// Owned copy of the full window state. The borrowed
    /// [`CenterWindow::state_view`] feeds the zero-copy streaming
    /// checkpoint writer; the training-checkpoint path clones because the
    /// snapshot must outlive the fit loop's borrows (DESIGN.md §12).
    pub(crate) fn owned_state(&self) -> WindowState {
        WindowState {
            entries: self
                .entries
                .iter()
                .map(|e| (e.points.clone(), e.raws.clone()))
                .collect(),
            scale: self.scale,
            init_point: self.init_point,
            tau: self.tau,
            cc_cache: self.cc_cache,
            updates_since_exact: self.updates_since_exact,
        }
    }

    /// Rebuild a window from an exported state — the exact inverse of
    /// [`CenterWindow::state_view`]. `total_points` is derived (it is
    /// always the sum of entry lengths); the caller (the artifact loader)
    /// has already validated index bounds and per-entry shape.
    pub(crate) fn from_state(s: WindowState) -> CenterWindow {
        assert!(s.tau >= 1);
        let total_points = s.entries.iter().map(|(pts, _)| pts.len()).sum();
        CenterWindow {
            entries: s
                .entries
                .into_iter()
                .map(|(points, raws)| {
                    assert_eq!(points.len(), raws.len(), "ragged window entry");
                    WindowEntry { points, raws }
                })
                .collect(),
            scale: s.scale,
            init_point: s.init_point,
            tau: s.tau,
            total_points,
            cc_cache: s.cc_cache,
            updates_since_exact: s.updates_since_exact,
        }
    }

    /// cc ← ‖Ĉ − e‖² where e = Σ w_p φ(p) is currently part of the support.
    fn subtract_from_cc(&mut self, gram: &dyn KernelProvider, pts: &[usize], ws: &[f64]) {
        let Some(cc) = self.cc_cache else { return };
        let mut e_dot_c = 0.0;
        for (&p, &w) in pts.iter().zip(ws.iter()) {
            e_dot_c += w * self.cross_with_point(gram, p);
        }
        let mut e_dot_e = 0.0;
        for (&p, &wp) in pts.iter().zip(ws.iter()) {
            if let Some(row) = gram.row_slice(p) {
                for (&q, &wq) in pts.iter().zip(ws.iter()) {
                    e_dot_e += wp * wq * row[q] as f64;
                }
            } else {
                for (&q, &wq) in pts.iter().zip(ws.iter()) {
                    e_dot_e += wp * wq * gram.eval(p, q);
                }
            }
        }
        self.cc_cache = Some((cc - 2.0 * e_dot_c + e_dot_e).max(0.0));
    }

    /// `‖Ĉ − other‖²` where `other` is another window over the same gram —
    /// used by tests to verify Lemma 3 empirically.
    pub fn sqdist_to(&self, other: &CenterWindow, gram: &dyn KernelProvider) -> f64 {
        let a: Vec<(usize, f64)> = self.support().collect();
        let b: Vec<(usize, f64)> = other.support().collect();
        // ‖A−B‖² = ⟨A,A⟩ − 2⟨A,B⟩ + ⟨B,B⟩ over combined support.
        let mut aa = 0.0;
        for &(ya, wa) in &a {
            for &(yb, wb) in &a {
                aa += wa * wb * gram.eval(ya, yb);
            }
        }
        let mut bb = 0.0;
        for &(ya, wa) in &b {
            for &(yb, wb) in &b {
                bb += wa * wb * gram.eval(ya, yb);
            }
        }
        let mut ab = 0.0;
        for &(ya, wa) in &a {
            for &(yb, wb) in &b {
                ab += wa * wb * gram.eval(ya, yb);
            }
        }
        (aa - 2.0 * ab + bb).max(0.0)
    }
}

/// Stamp sentinel: the point has never been refreshed (its `px` row is
/// garbage and must be rebuilt from the seed columns before any replay).
const STAMP_UNINIT: u32 = u32::MAX;

/// One center update in the replay log: everything needed to re-apply
/// `px ← (1−α)·px + α·⟨φ(x), cm(B^j)⟩` for any point, later.
struct UpdateEntry {
    /// Center index j.
    center: u32,
    /// Learning rate α of this update.
    alpha: f64,
    /// Weighted mass of the batch members (the `cm` denominator).
    mass: f64,
    /// Member columns: `cols[start..end]`, assignment order, duplicates
    /// kept — the replay's accumulation order is pinned to it.
    start: usize,
    end: usize,
}

/// Algorithm 1's lazy, generation-stamped assignment state (DESIGN.md §9).
///
/// Replaces the eager full-n `px` sweep: each point's row of
/// `px[x][j] = ⟨φ(x), C_j⟩` carries the *generation* (log length) it was
/// last refreshed at, and a refresh replays only the update entries
/// appended since — the same `(1−α)·px + α·cross/mass` recursion steps, in
/// the same order, over the same kernel values the eager sweep used, so
/// refreshed rows are **bit-identical** to eagerly maintained ones. An
/// iteration refreshes exactly the b sampled points (`Õ(kb·Δ)` where Δ is
/// the support appended since their last refresh); the full dataset is
/// visited once, in [`LazyAssignState::finalize`].
///
/// Kernel values come from the provider's fastest bit-stable path: direct
/// row loads on materialized tables, a planned gather (tile-batched on the
/// streaming provider, panel-filled on feature kernels) for full replays,
/// and per-element `eval` for short suffixes.
pub struct LazyAssignState {
    k: usize,
    /// Column universe of the log: `cols[..k]` are the seed columns, entry
    /// member columns follow append-only. A full replay gathers one row
    /// against this whole list in a single planned call.
    cols: Vec<u32>,
    /// The update log, in application order.
    entries: Vec<UpdateEntry>,
    /// `px[x·k + j] = ⟨φ(x), C_j⟩` as of generation `stamp[x]`.
    px: Vec<f64>,
    /// Per-point generation: number of log entries already applied to the
    /// point's row ([`STAMP_UNINIT`] = row not yet initialized).
    stamp: Vec<u32>,
    /// Gather plan over `cols[..planned]` (non-materialized providers).
    plan: Option<GatherPlan>,
    planned: usize,
    /// Scratch for refresh bookkeeping: unique (point, old stamp) pairs.
    pending: Vec<(usize, u32)>,
    /// Reusable per-worker gather buffers — hoisted out of the iteration
    /// loop so a fit performs no per-iteration scratch allocations.
    scratch: Mutex<Vec<Vec<f64>>>,
}

impl LazyAssignState {
    /// Fresh state for `n` points, `k` centers seeded at dataset points
    /// `seeds`. O(n) bookkeeping, **zero** kernel evaluations — a point's
    /// initial `px` row (`K(x, seed_j)`) is built lazily on first refresh.
    pub fn new(n: usize, seeds: &[usize]) -> LazyAssignState {
        let k = seeds.len();
        assert!(k >= 1, "need at least one center");
        assert!(n > 0 && n - 1 <= u32::MAX as usize, "n out of u32 range");
        LazyAssignState {
            k,
            cols: seeds.iter().map(|&s| s as u32).collect(),
            entries: Vec::new(),
            px: vec![0.0f64; n * k],
            stamp: vec![STAMP_UNINIT; n],
            plan: None,
            planned: 0,
            pending: Vec::new(),
            scratch: Mutex::new(Vec::new()),
        }
    }

    /// Number of updates appended so far (the current generation).
    pub fn generation(&self) -> usize {
        self.entries.len()
    }

    /// The point's `px` row — valid only after a refresh in the current
    /// generation (callers refresh the batch, then read).
    pub fn px_row(&self, x: usize) -> &[f64] {
        &self.px[x * self.k..(x + 1) * self.k]
    }

    /// Append one center update to the log: center `j` moved toward the
    /// weighted mean of `members` (weighted mass `mass`) with rate `alpha`.
    /// O(b_j) — nothing is applied to any `px` row here.
    pub fn append_update(&mut self, j: usize, alpha: f64, mass: f64, members: &[usize]) {
        debug_assert!(j < self.k && alpha > 0.0);
        assert!(self.entries.len() < STAMP_UNINIT as usize - 1, "update log overflow");
        let start = self.cols.len();
        self.cols.extend(members.iter().map(|&y| y as u32));
        self.entries.push(UpdateEntry {
            center: j as u32,
            alpha,
            mass,
            start,
            end: self.cols.len(),
        });
    }

    /// Bring every point in `points` (duplicates fine) to the current
    /// generation: replay the entries appended since each point's stamp,
    /// in parallel over the pool. Rows already current are skipped.
    pub fn refresh(
        &mut self,
        gram: &dyn KernelProvider,
        points: &[usize],
        weights: Option<&[f64]>,
    ) {
        let cur = self.entries.len() as u32;
        self.pending.clear();
        self.pending.extend(points.iter().map(|&x| (x, 0u32)));
        self.pending.sort_unstable_by_key(|p| p.0);
        self.pending.dedup_by_key(|p| p.0);
        let stamp = &mut self.stamp;
        let mut any_full = false;
        self.pending.retain_mut(|p| {
            let s = stamp[p.0];
            if s == cur {
                return false;
            }
            p.1 = s;
            stamp[p.0] = cur;
            any_full |= s == STAMP_UNINIT;
            true
        });
        if self.pending.is_empty() {
            return;
        }
        if any_full && gram.row_slice(self.pending[0].0).is_none() {
            self.ensure_plan(gram);
        }
        let (k, entries, cols) = (self.k, &self.entries, &self.cols);
        let (plan, scratch) = (self.plan.as_ref(), &self.scratch);
        let pend: &[(usize, u32)] = &self.pending;
        let view = SharedSlice::new(&mut self.px);
        let view = &view;
        par_dynamic(pend.len(), |i| {
            let (x, old) = pend[i];
            // SAFETY: pending points are deduplicated, so the k-wide row
            // ranges handed to concurrent tasks are pairwise disjoint.
            let row = unsafe { view.chunk_mut(x * k, k) };
            replay_row(gram, row, x, old, entries, cols, plan, weights, scratch);
        });
    }

    /// The single full-dataset pass: bring every row to the final
    /// generation (one blocked replay over the whole log — the `K(X, S)·A`
    /// contraction, served by row loads / planned tile gathers / panel
    /// fills depending on the provider) and emit each point's assignment
    /// and min squared distance under the final centers, fused in the same
    /// cache-warm visit. Consumes the state: replaying a log twice would
    /// double-apply it.
    pub fn finalize(
        mut self,
        gram: &dyn KernelProvider,
        cc: &[f64],
        weights: Option<&[f64]>,
    ) -> (Vec<usize>, Vec<f64>) {
        assert_eq!(cc.len(), self.k);
        let n = self.stamp.len();
        if gram.row_slice(0).is_none() {
            self.ensure_plan(gram);
        }
        let cur = self.entries.len() as u32;
        let LazyAssignState { k, cols, entries, mut px, stamp, plan, scratch, .. } = self;
        let plan = plan.as_ref();
        let mut assign = vec![0usize; n];
        let mut mins = vec![0.0f64; n];
        {
            let (entries, cols, stamp, scratch) = (&entries, &cols, &stamp, &scratch);
            par_rows_mut3(
                &mut px,
                k,
                &mut assign,
                1,
                &mut mins,
                1,
                |row0, pxb, ab, mb| {
                    for (r, row) in pxb.chunks_mut(k).enumerate() {
                        let x = row0 + r;
                        let old = stamp[x];
                        if old != cur {
                            replay_row(gram, row, x, old, entries, cols, plan, weights, scratch);
                        }
                        let kxx = gram.self_k(x);
                        let mut best = 0usize;
                        let mut bestv = f64::INFINITY;
                        for (j, &pxj) in row.iter().enumerate() {
                            let d = (kxx - 2.0 * pxj + cc[j]).max(0.0);
                            if d < bestv {
                                best = j;
                                bestv = d;
                            }
                        }
                        ab[r] = best;
                        mb[r] = bestv;
                    }
                },
            );
        }
        (assign, mins)
    }

    /// Make the gather plan cover the whole column list (providers without
    /// direct row access). Appends since the last call are merged through
    /// [`KernelProvider::plan_gather_extend`], so the per-iteration cost is
    /// linear in the plan, not `O(len·log len)` re-sorts.
    fn ensure_plan(&mut self, gram: &dyn KernelProvider) {
        if self.planned == self.cols.len() && self.plan.is_some() {
            return;
        }
        match self.plan.as_mut() {
            None => self.plan = Some(gram.plan_gather(&self.cols)),
            Some(plan) => gram.plan_gather_extend(plan, &self.cols[self.planned..]),
        }
        self.planned = self.cols.len();
    }
}

/// Replay the log suffix `entries[old_stamp..]` onto one point's `px` row —
/// the bit-identity core. Every branch accumulates each entry's cross term
/// as one sequential f64 chain in member order and applies
/// `(1−α)·px + α·cross/mass`, exactly the arithmetic of the removed eager
/// sweep; the branches differ only in where the kernel values come from
/// (materialized row, planned gather, per-element eval), which the
/// providers pin to identical values.
#[allow(clippy::too_many_arguments)]
fn replay_row(
    gram: &dyn KernelProvider,
    row: &mut [f64],
    x: usize,
    old_stamp: u32,
    entries: &[UpdateEntry],
    cols: &[u32],
    plan: Option<&GatherPlan>,
    weights: Option<&[f64]>,
    scratch: &Mutex<Vec<Vec<f64>>>,
) {
    let k = row.len();
    if let Some(g) = gram.row_slice(x) {
        // Materialized fast path: direct f32 row loads.
        let from = if old_stamp == STAMP_UNINIT {
            for (r, &s) in row.iter_mut().zip(cols[..k].iter()) {
                *r = g[s as usize] as f64;
            }
            0
        } else {
            old_stamp as usize
        };
        for e in &entries[from..] {
            let mut cross = 0.0;
            match weights {
                None => {
                    for &y in &cols[e.start..e.end] {
                        cross += g[y as usize] as f64;
                    }
                }
                Some(w) => {
                    for &y in &cols[e.start..e.end] {
                        cross += w[y as usize] * g[y as usize] as f64;
                    }
                }
            }
            apply_step(row, e, cross);
        }
    } else if old_stamp == STAMP_UNINIT {
        // Full replay: one planned gather of the entire column universe
        // (tile-batched on the streaming provider, panel-filled on feature
        // kernels), then the recursion reads from the buffer.
        let plan = plan.expect("full lazy replay needs a gather plan");
        debug_assert_eq!(plan.len(), cols.len(), "plan lags the update log");
        let mut buf = scratch.lock().unwrap().pop().unwrap_or_default();
        buf.resize(cols.len(), 0.0);
        gram.row_gather_planned(x, plan, &mut buf);
        row.copy_from_slice(&buf[..k]);
        for e in entries {
            let mut cross = 0.0;
            match weights {
                None => {
                    for &v in &buf[e.start..e.end] {
                        cross += v;
                    }
                }
                Some(w) => {
                    for (&y, &v) in cols[e.start..e.end].iter().zip(&buf[e.start..e.end]) {
                        cross += w[y as usize] * v;
                    }
                }
            }
            apply_step(row, e, cross);
        }
        scratch.lock().unwrap().push(buf);
    } else {
        // Short suffix on a non-materialized provider: per-element eval
        // (same values as the gathered path by the provider contract).
        for e in &entries[old_stamp as usize..] {
            let mut cross = 0.0;
            match weights {
                None => {
                    for &y in &cols[e.start..e.end] {
                        cross += gram.eval(x, y as usize);
                    }
                }
                Some(w) => {
                    for &y in &cols[e.start..e.end] {
                        cross += w[y as usize] * gram.eval(x, y as usize);
                    }
                }
            }
            apply_step(row, e, cross);
        }
    }
}

/// One recursion step of the lazy replay — the same expression, in the same
/// f64 evaluation order, as the eager sweep's update line.
#[inline]
fn apply_step(row: &mut [f64], e: &UpdateEntry, cross: f64) {
    let j = e.center as usize;
    row[j] = (1.0 - e.alpha) * row[j] + e.alpha * cross / e.mass;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};
    use crate::kernels::{Gram, KernelFunction};
    use crate::util::rng::Rng;

    fn fixture() -> crate::data::Dataset {
        let mut rng = Rng::seeded(55);
        blobs(&SyntheticSpec::new(120, 3, 3), &mut rng)
    }

    #[test]
    fn fresh_window_is_the_init_point() {
        let w = CenterWindow::new(7, 100);
        let sup: Vec<_> = w.support().collect();
        assert_eq!(sup, vec![(7, 1.0)]);
        assert!(w.is_exact());
        assert_eq!(w.support_len(), 1);
    }

    #[test]
    fn untruncated_weights_sum_to_one() {
        let mut rng = Rng::seeded(1);
        let mut w = CenterWindow::new(0, usize::MAX);
        for _ in 0..30 {
            let bj = 1 + rng.below(8);
            let pts: Vec<usize> = (0..bj).map(|_| rng.below(120)).collect();
            let alpha = (bj as f64 / 32.0).sqrt();
            w.apply_update(alpha, &pts, None);
            assert!((w.weight_sum() - 1.0).abs() < 1e-9, "sum={}", w.weight_sum());
            assert!(w.is_exact());
        }
    }

    #[test]
    fn truncated_weights_at_most_one_and_support_bounded() {
        let mut rng = Rng::seeded(2);
        let tau = 20;
        let b = 16;
        let mut w = CenterWindow::new(0, tau);
        for _ in 0..100 {
            let bj = 1 + rng.below(b);
            let pts: Vec<usize> = (0..bj).map(|_| rng.below(120)).collect();
            w.apply_update((bj as f64 / b as f64).sqrt(), &pts, None);
            let sum = w.weight_sum();
            assert!(sum <= 1.0 + 1e-9, "sum={sum}");
            assert!(sum > 0.0);
            // Support ≤ τ + b (+1 for init while exact).
            assert!(w.support_len() <= tau + b + 1, "support={}", w.support_len());
        }
        assert!(!w.is_exact(), "100 updates of ≥1 point must have trimmed τ=20");
    }

    #[test]
    fn window_keeps_minimal_suffix_reaching_tau() {
        let mut w = CenterWindow::new(0, 10);
        // Batches of 4 points each: after trim the suffix point count must be
        // ≥ τ only including the oldest entry, i.e. in [τ, τ+4).
        for i in 0..20 {
            let pts: Vec<usize> = (0..4).map(|p| (i * 4 + p) % 100).collect();
            w.apply_update(0.5, &pts, None);
        }
        assert!(w.total_points >= 10 && w.total_points < 14, "{}", w.total_points);
    }

    #[test]
    fn alpha_one_resets_history() {
        let mut w = CenterWindow::new(3, 50);
        w.apply_update(0.5, &[1, 2], None);
        w.apply_update(1.0, &[9, 10, 11], None);
        let sup: Vec<_> = w.support().collect();
        assert_eq!(sup.len(), 3);
        assert!(sup.iter().all(|&(p, _)| p >= 9));
        assert!((w.weight_sum() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn alpha_zero_is_noop() {
        let mut w = CenterWindow::new(3, 50);
        w.apply_update(0.5, &[1, 2], None);
        let before: Vec<_> = w.support().collect();
        w.apply_update(0.0, &[], None);
        let after: Vec<_> = w.support().collect();
        assert_eq!(before, after);
    }

    #[test]
    fn coefficients_match_recursive_expansion() {
        // Hand-check: C₁ = φ(0); α₁=0.5 with B={1}; α₂=0.25 with B={2,3}.
        // C₃ = 0.5·0.75·φ(0)... wait: C₂ = 0.5φ(0)+0.5φ(1);
        // C₃ = 0.75·C₂ + 0.25·cm({2,3})
        //    = 0.375φ(0) + 0.375φ(1) + 0.125φ(2) + 0.125φ(3).
        let mut w = CenterWindow::new(0, usize::MAX);
        w.apply_update(0.5, &[1], None);
        w.apply_update(0.25, &[2, 3], None);
        let sup: std::collections::BTreeMap<usize, f64> = w.support().collect();
        assert!((sup[&0] - 0.375).abs() < 1e-12);
        assert!((sup[&1] - 0.375).abs() < 1e-12);
        assert!((sup[&2] - 0.125).abs() < 1e-12);
        assert!((sup[&3] - 0.125).abs() < 1e-12);
    }

    #[test]
    fn weighted_cm_uses_dataset_weights() {
        let mut w = CenterWindow::new(0, usize::MAX);
        // Points 1 and 2 with weights 3 and 1 → cm = 0.75φ(1) + 0.25φ(2).
        w.apply_update(1.0, &[1, 2], Some(&[3.0, 1.0]));
        let sup: std::collections::BTreeMap<usize, f64> = w.support().collect();
        assert!((sup[&1] - 0.75).abs() < 1e-12);
        assert!((sup[&2] - 0.25).abs() < 1e-12);
    }

    #[test]
    fn self_inner_matches_bruteforce_and_caches() {
        let ds = fixture();
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 6.0 });
        let mut rng = Rng::seeded(3);
        let mut w = CenterWindow::new(5, 30);
        for _ in 0..10 {
            let pts: Vec<usize> = (0..6).map(|_| rng.below(ds.n)).collect();
            w.apply_update(0.4, &pts, None);
        }
        let cc = w.self_inner(&gram);
        // Brute force over support.
        let sup: Vec<_> = w.support().collect();
        let mut brute = 0.0;
        for &(a, wa) in &sup {
            for &(b, wb) in &sup {
                brute += wa * wb * gram.eval(a, b);
            }
        }
        assert!((cc - brute).abs() < 1e-10);
        assert_eq!(w.self_inner(&gram), cc); // cached value identical
    }

    #[test]
    fn cross_with_point_matches_bruteforce() {
        let ds = fixture();
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 6.0 });
        let mut w = CenterWindow::new(2, usize::MAX);
        w.apply_update(0.5, &[10, 20, 30], None);
        let x = 40;
        let got = w.cross_with_point(&gram, x);
        let want: f64 = w.support().map(|(y, c)| c * gram.eval(x, y)).sum();
        assert!((got - want).abs() < 1e-12);
    }

    #[test]
    fn scale_underflow_renormalizes_transparently() {
        let mut w = CenterWindow::new(0, 5);
        // α close to 1 ⇒ scale shrinks brutally fast; 2000 updates would
        // underflow any fixed scale without renormalization.
        for i in 0..2000 {
            w.apply_update(0.999, &[i % 50], None);
            assert!(w.weight_sum().is_finite());
        }
        let sum = w.weight_sum();
        // Window of ≤ 5+1 recent points with α≈1: total weight ≈ 1.
        assert!(sum > 0.99 && sum <= 1.0 + 1e-9, "sum={sum}");
    }

    #[test]
    fn incremental_cc_matches_bruteforce_over_long_streams() {
        // apply_update_cc's maintained ⟨Ĉ,Ĉ⟩ must track the brute-force
        // value through appends, trims, init drop, α=1 resets, and weights.
        let ds = fixture();
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 6.0 });
        let mat = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 6.0 }).materialize();
        for g in [&gram, &mat] {
            let mut rng = Rng::seeded(12);
            let mut inc = CenterWindow::new(3, 25);
            let mut brute = CenterWindow::new(3, 25);
            for step in 0..120 {
                let bj = 1 + rng.below(12);
                let pts: Vec<usize> = (0..bj).map(|_| rng.below(ds.n)).collect();
                let alpha = if step == 60 { 1.0 } else { (bj as f64 / 16.0).min(1.0).sqrt() };
                let w: Option<Vec<f64>> = if step % 3 == 0 {
                    Some(pts.iter().map(|&p| 1.0 + (p % 4) as f64).collect())
                } else {
                    None
                };
                inc.apply_update_cc(alpha, &pts, w.as_deref(), g);
                brute.apply_update(alpha, &pts, w.as_deref());
                let got = inc.self_inner(g);
                let want = brute.self_inner(g);
                assert!(
                    (got - want).abs() < 1e-6,
                    "step {step}: incremental {got} vs brute {want}"
                );
            }
        }
    }

    #[test]
    fn export_import_state_is_bitwise_transparent() {
        // A window round-tripped through WindowState must expose the same
        // support bit-for-bit AND keep evolving identically (cc cache and
        // drift counter included) under further updates.
        let ds = fixture();
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 6.0 });
        let mut rng = Rng::seeded(9);
        let mut original = CenterWindow::new(4, 15);
        for _ in 0..25 {
            let pts: Vec<usize> =
                (0..1 + rng.below(6)).map(|_| rng.below(ds.n)).collect();
            original.apply_update_cc(0.4, &pts, None, &gram);
        }
        // Round-trip through the borrowed writer view and the owned loader
        // state — exactly what snapshot → resume does.
        let view = original.state_view();
        let mut restored = CenterWindow::from_state(WindowState {
            entries: view
                .entries
                .iter()
                .map(|(p, r)| (p.to_vec(), r.to_vec()))
                .collect(),
            scale: view.scale,
            init_point: view.init_point,
            tau: 15,
            cc_cache: view.cc_cache,
            updates_since_exact: view.updates_since_exact,
        });
        let a: Vec<_> = original.support().collect();
        let b: Vec<_> = restored.support().collect();
        assert_eq!(a.len(), b.len());
        for ((ya, wa), (yb, wb)) in a.iter().zip(b.iter()) {
            assert_eq!(ya, yb);
            assert_eq!(wa.to_bits(), wb.to_bits());
        }
        for _ in 0..10 {
            let pts: Vec<usize> = (0..3).map(|_| rng.below(ds.n)).collect();
            original.apply_update_cc(0.3, &pts, None, &gram);
            restored.apply_update_cc(0.3, &pts, None, &gram);
            assert_eq!(
                original.self_inner(&gram).to_bits(),
                restored.self_inner(&gram).to_bits()
            );
        }
    }

    /// Eager reference: the removed full-n sweep's recursion, per point.
    /// `px ← (1−α)px + α·cross/mass` with cross accumulated in member
    /// order from per-element eval — the op sequence the lazy replay must
    /// reproduce bit-for-bit.
    #[allow(clippy::too_many_arguments)]
    fn eager_apply(
        gram: &dyn KernelProvider,
        px: &mut [f64],
        k: usize,
        j: usize,
        alpha: f64,
        mass: f64,
        members: &[usize],
        weights: Option<&[f64]>,
    ) {
        let n = gram.n();
        for x in 0..n {
            let mut cross = 0.0;
            match weights {
                None => {
                    for &y in members {
                        cross += gram.eval(x, y);
                    }
                }
                Some(w) => {
                    for &y in members {
                        cross += w[y] * gram.eval(x, y);
                    }
                }
            }
            px[x * k + j] = (1.0 - alpha) * px[x * k + j] + alpha * cross / mass;
        }
    }

    #[test]
    fn lazy_refresh_is_bit_identical_to_eager_recursion() {
        // Drive a LazyAssignState and an eager full-table reference with
        // the same update stream, refreshing random subsets at random
        // times; every refreshed row must match the eager table to the
        // bit, on every provider flavour, weighted and not.
        let ds = fixture();
        let fly = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 6.0 });
        let mat = fly.materialize();
        let w: Vec<f64> = (0..ds.n).map(|i| 1.0 + (i % 5) as f64).collect();
        for g in [&fly, &mat] {
            for weights in [None, Some(w.as_slice())] {
                let mut rng = Rng::seeded(21);
                let k = 3;
                let seeds = [4usize, 40, 90];
                let mut lazy = LazyAssignState::new(ds.n, &seeds);
                let mut px = vec![0.0f64; ds.n * k];
                for x in 0..ds.n {
                    for (j, &s) in seeds.iter().enumerate() {
                        px[x * k + j] = g.eval(x, s);
                    }
                }
                for _step in 0..15 {
                    let bj = 1 + rng.below(8);
                    let members: Vec<usize> = (0..bj).map(|_| rng.below(ds.n)).collect();
                    let j = rng.below(k);
                    let alpha = (bj as f64 / 16.0).sqrt();
                    let mass = match weights {
                        None => members.len() as f64,
                        Some(w) => members.iter().map(|&y| w[y]).sum(),
                    };
                    eager_apply(g, &mut px, k, j, alpha, mass, &members, weights);
                    lazy.append_update(j, alpha, mass, &members);
                    // Refresh a random subset (with duplicates) mid-stream.
                    let probe: Vec<usize> = (0..6).map(|_| rng.below(ds.n)).collect();
                    lazy.refresh(g, &probe, weights);
                    for &x in &probe {
                        for j in 0..k {
                            assert_eq!(
                                lazy.px_row(x)[j].to_bits(),
                                px[x * k + j].to_bits(),
                                "px[{x},{j}] diverged mid-stream"
                            );
                        }
                    }
                }
                // Finalize must refresh every remaining row identically and
                // fuse the same argmin the eager sweep would compute.
                let cc = vec![1.0f64; k];
                let (assign, mins) = lazy.finalize(g, &cc, weights);
                for x in 0..ds.n {
                    let kxx = g.self_k(x);
                    let mut best = 0usize;
                    let mut bestv = f64::INFINITY;
                    for j in 0..k {
                        let d = (kxx - 2.0 * px[x * k + j] + cc[j]).max(0.0);
                        if d < bestv {
                            best = j;
                            bestv = d;
                        }
                    }
                    assert_eq!(assign[x], best, "assignment diverged at {x}");
                    assert_eq!(mins[x].to_bits(), bestv.to_bits(), "min at {x}");
                }
            }
        }
    }

    #[test]
    fn lazy_state_with_no_updates_assigns_from_seeds() {
        // generation 0 (max_iters = 0 in Algorithm 1): finalize must build
        // every row from the seed columns and argmin against them.
        let ds = fixture();
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 6.0 });
        let seeds = [7usize, 70];
        let lazy = LazyAssignState::new(ds.n, &seeds);
        assert_eq!(lazy.generation(), 0);
        let cc: Vec<f64> = seeds.iter().map(|&s| gram.self_k(s)).collect();
        let (assign, mins) = lazy.finalize(&gram, &cc, None);
        for x in 0..ds.n {
            let mut best = 0;
            let mut bestv = f64::INFINITY;
            for (j, &s) in seeds.iter().enumerate() {
                let d = (gram.self_k(x) - 2.0 * gram.eval(x, s) + cc[j]).max(0.0);
                if d < bestv {
                    best = j;
                    bestv = d;
                }
            }
            assert_eq!(assign[x], best);
            assert!((mins[x] - bestv).abs() < 1e-15);
        }
        // The seed points themselves are at distance 0 from their center.
        assert_eq!(assign[7], 0);
        assert!(mins[7].abs() < 1e-12);
    }

    #[test]
    fn refresh_skips_current_rows_and_tolerates_duplicates() {
        let ds = fixture();
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 6.0 });
        let mut lazy = LazyAssignState::new(ds.n, &[0, 1]);
        lazy.append_update(0, 0.5, 3.0, &[5, 6, 7]);
        lazy.refresh(&gram, &[9, 9, 3, 9], None);
        let before: Vec<u64> = lazy.px_row(9).iter().map(|v| v.to_bits()).collect();
        // A second refresh at the same generation must be a no-op.
        lazy.refresh(&gram, &[9, 3], None);
        let after: Vec<u64> = lazy.px_row(9).iter().map(|v| v.to_bits()).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn lemma3_tau_formula() {
        // τ = ⌈b·ln²(28γ/ε)⌉
        let tau = CenterWindow::lemma3_tau(100, 1.0, 0.1);
        let l = (280.0f64).ln();
        assert_eq!(tau, (100.0 * l * l).ceil() as usize);
        // Degenerate ε ≥ 28γ clamps to b.
        assert_eq!(CenterWindow::lemma3_tau(100, 1.0, 100.0), 100);
    }

    #[test]
    fn truncation_error_obeys_lemma3_bound() {
        // Run identical update streams through an untruncated window and a
        // τ = lemma3 window; final centers must differ by ≤ ε/28 in feature
        // space (Lemma 3), using the β learning rate.
        let ds = fixture();
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 6.0 });
        let epsilon = 0.5f64;
        let gamma = 1.0;
        let b = 16;
        let tau = CenterWindow::lemma3_tau(b, gamma, epsilon);
        let mut exact = CenterWindow::new(0, usize::MAX);
        let mut trunc = CenterWindow::new(0, tau);
        let mut rng = Rng::seeded(8);
        for _ in 0..60 {
            let bj = 1 + rng.below(b);
            let pts: Vec<usize> = (0..bj).map(|_| rng.below(ds.n)).collect();
            let alpha = (bj as f64 / b as f64).sqrt();
            exact.apply_update(alpha, &pts, None);
            trunc.apply_update(alpha, &pts, None);
        }
        let err = trunc.sqdist_to(&exact, &gram).sqrt();
        assert!(err <= epsilon / 28.0 + 1e-9, "err={err} bound={}", epsilon / 28.0);
    }
}
