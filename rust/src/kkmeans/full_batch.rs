//! Full-batch kernel k-means — Lloyd's algorithm in feature space
//! (Dhillon, Guan & Kulis 2004). The paper's baseline.
//!
//! Every iteration assigns all `n` points to the closest implicit center
//! `c_j = cm(A_j)` using
//!
//! `Δ(x, c_j) = K(x,x) − (2/|A_j|)·Σ_{y∈A_j} K(x,y) + (1/|A_j|²)·Σ_{y,z∈A_j} K(y,z)`
//!
//! which costs `O(n²)` kernel evaluations — the cost the paper's mini-batch
//! algorithms remove. Supports the weighted variant (footnote 1) via
//! per-point weights.

use super::backend::argmin_rows;
use super::init::choose_centers;
use super::{FitResult, Init};
use crate::kernels::KernelProvider;
use crate::util::parallel::par_rows_mut;
use crate::util::rng::Rng;
use crate::util::timing::{Profiler, Stopwatch};

/// Configuration for [`FullBatchKernelKMeans`].
#[derive(Clone, Debug)]
pub struct FullBatchConfig {
    /// Number of clusters.
    pub k: usize,
    /// Maximum Lloyd iterations.
    pub max_iters: usize,
    /// Early stop when the objective improves by less than ε (`None` ⇒ run
    /// until assignments stabilize or `max_iters`).
    pub epsilon: Option<f64>,
    /// Center initialization method.
    pub init: Init,
    /// Optional per-point weights (weighted kernel k-means).
    pub weights: Option<Vec<f64>>,
}

impl Default for FullBatchConfig {
    fn default() -> Self {
        FullBatchConfig {
            k: 2,
            max_iters: 200,
            epsilon: None,
            init: Init::default(),
            weights: None,
        }
    }
}

/// Full-batch kernel k-means runner.
pub struct FullBatchKernelKMeans {
    cfg: FullBatchConfig,
}

impl FullBatchKernelKMeans {
    /// Wrap a configuration (validates weights).
    pub fn new(cfg: FullBatchConfig) -> Self {
        if let Some(w) = &cfg.weights {
            assert!(w.iter().all(|&x| x > 0.0), "weights must be positive");
        }
        FullBatchKernelKMeans { cfg }
    }

    /// Run Lloyd's algorithm in feature space.
    pub fn fit(&self, gram: &dyn KernelProvider, rng: &mut Rng) -> FitResult {
        let n = gram.n();
        let k = self.cfg.k;
        assert!(k >= 1 && k <= n);
        let mut prof = Profiler::new();

        // Initialize: centers are single points; realize as an assignment by
        // one assignment pass against those points.
        let sw = Stopwatch::start();
        let seeds = choose_centers(gram, k, self.cfg.init, rng);
        let mut assignments: Vec<usize> = (0..n)
            .map(|x| {
                let mut best = 0;
                let mut bestv = f64::INFINITY;
                for (j, &s) in seeds.iter().enumerate() {
                    let d = super::init::feature_sqdist(gram, x, s);
                    if d < bestv {
                        best = j;
                        bestv = d;
                    }
                }
                best
            })
            .collect();
        prof.add("init", sw.secs());

        let weights = self.cfg.weights.as_deref();
        let mut history = Vec::new();
        let mut iterations = 0;
        let mut converged = false;
        let mut prev_obj = f64::INFINITY;
        // Full-batch improvements are exact (no sampling noise), so ε is
        // always the legacy single-observation rule here; the stopper only
        // adds the recorded decision sequence.
        let mut stopper = self
            .cfg
            .epsilon
            .map(|eps| super::termination::EpsilonStopper::new(eps, super::TerminationMode::SingleBatch));

        for iter in 0..self.cfg.max_iters {
            iterations += 1;
            let sw = Stopwatch::start();
            // Cluster membership lists + weight mass.
            let mut members: Vec<Vec<usize>> = vec![Vec::new(); k];
            for (x, &j) in assignments.iter().enumerate() {
                members[j].push(x);
            }
            let mass: Vec<f64> = members
                .iter()
                .map(|m| match weights {
                    None => m.len() as f64,
                    Some(w) => m.iter().map(|&x| w[x]).sum(),
                })
                .collect();

            // term3_j = (1/W_j²)·ΣΣ w_y w_z K(y,z) — O(Σ|A_j|²).
            let term3: Vec<f64> = (0..k)
                .map(|j| {
                    if members[j].is_empty() {
                        return f64::INFINITY; // empty cluster attracts nobody
                    }
                    let pts = &members[j];
                    let wj = mass[j];
                    let mut s = 0.0;
                    for (a, &y) in pts.iter().enumerate() {
                        let wy = weights.map(|w| w[y]).unwrap_or(1.0);
                        s += wy * wy * gram.self_k(y);
                        if let Some(grow) = gram.row_slice(y) {
                            match weights {
                                None => {
                                    let mut acc = 0.0;
                                    for &z in pts.iter().skip(a + 1) {
                                        acc += grow[z] as f64;
                                    }
                                    s += 2.0 * acc;
                                }
                                Some(w) => {
                                    for &z in pts.iter().skip(a + 1) {
                                        s += 2.0 * wy * w[z] * grow[z] as f64;
                                    }
                                }
                            }
                        } else {
                            for &z in pts.iter().skip(a + 1) {
                                let wz = weights.map(|w| w[z]).unwrap_or(1.0);
                                s += 2.0 * wy * wz * gram.eval(y, z);
                            }
                        }
                    }
                    s / (wj * wj)
                })
                .collect();
            prof.add("term3", sw.secs());

            // dist(x, j) = K(x,x) − 2/W_j·Σ w_y K(x,y) + term3_j, all x, j.
            let sw = Stopwatch::start();
            let mut dist = vec![0.0f64; n * k];
            {
                let members = &members;
                let mass = &mass;
                let term3 = &term3;
                par_rows_mut(&mut dist, k, |row0, block| {
                    for (r, row) in block.chunks_mut(k).enumerate() {
                        let x = row0 + r;
                        let kxx = gram.self_k(x);
                        // §Perf: hoisted row slice — direct loads in the
                        // O(n²) inner loop.
                        let grow = gram.row_slice(x);
                        for j in 0..k {
                            if members[j].is_empty() {
                                row[j] = f64::INFINITY;
                                continue;
                            }
                            let mut cross = 0.0;
                            match (grow, weights) {
                                (Some(g), None) => {
                                    for &y in &members[j] {
                                        cross += g[y] as f64;
                                    }
                                }
                                (Some(g), Some(w)) => {
                                    for &y in &members[j] {
                                        cross += w[y] * g[y] as f64;
                                    }
                                }
                                (None, None) => {
                                    for &y in &members[j] {
                                        cross += gram.eval(x, y);
                                    }
                                }
                                (None, Some(w)) => {
                                    for &y in &members[j] {
                                        cross += w[y] * gram.eval(x, y);
                                    }
                                }
                            }
                            row[j] = (kxx - 2.0 * cross / mass[j] + term3[j]).max(0.0);
                        }
                    }
                });
            }
            let (new_assignments, mins) = argmin_rows(&dist, k);
            prof.add("assign", sw.secs());

            let points: Vec<usize> = (0..n).collect();
            let obj = super::objective::weighted_mean(&points, &mins, weights);
            history.push(obj);

            let changed = new_assignments
                .iter()
                .zip(assignments.iter())
                .filter(|(a, b)| a != b)
                .count();
            assignments = new_assignments;

            if changed == 0 {
                converged = true;
                break;
            }
            if let Some(stopper) = stopper.as_mut() {
                if stopper.observe(iter, prev_obj - obj) {
                    converged = true;
                    break;
                }
            }
            prev_obj = obj;
        }

        let objective = *history.last().unwrap_or(&f64::NAN);
        FitResult {
            assignments,
            objective,
            history,
            iterations,
            converged,
            decisions: stopper
                .map(super::termination::EpsilonStopper::into_decisions)
                .unwrap_or_default(),
            profiler: prof,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, rings, SyntheticSpec};
    use crate::kernels::{Gram, KernelFunction};
    use crate::metrics::ari;

    #[test]
    fn recovers_separated_blobs() {
        let mut rng = Rng::seeded(42);
        let ds = blobs(
            &SyntheticSpec::new(300, 4, 3).with_std(0.3).with_separation(8.0),
            &mut rng,
        );
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 20.0 });
        let cfg = FullBatchConfig { k: 3, max_iters: 50, ..Default::default() };
        let res = FullBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
        let score = ari(ds.labels.as_ref().unwrap(), &res.assignments);
        assert!(score > 0.95, "ARI={score}");
        assert!(res.converged);
    }

    #[test]
    fn objective_monotonically_nonincreasing() {
        let mut rng = Rng::seeded(43);
        let ds = blobs(&SyntheticSpec::new(200, 3, 4), &mut rng);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 8.0 });
        let cfg = FullBatchConfig { k: 4, max_iters: 30, ..Default::default() };
        let res = FullBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
        for w in res.history.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "objective increased: {:?}", w);
        }
    }

    #[test]
    fn separates_rings_where_linear_kmeans_cannot() {
        // The heat kernel (paper Appendix C) diffuses affinity within each
        // ring (a connected knn component) and none across, so kernel
        // k-means separates concentric rings that plain k-means (see
        // kmeans::lloyd tests) garbles. The raw knn kernel is too sparse for
        // single-point k-means++ seeds (all non-neighbours tie at zero).
        let mut rng = Rng::seeded(44);
        let ds = rings(400, 2, 2, 0.04, &mut rng);
        let gram = crate::kernels::graph::heat_kernel(&ds, 10, 500.0);
        let cfg = FullBatchConfig { k: 2, max_iters: 60, ..Default::default() };
        let mut best = 0.0f64;
        for seed in 0..5 {
            let mut r = Rng::seeded(seed);
            let res = FullBatchKernelKMeans::new(cfg.clone()).fit(&gram, &mut r);
            best = best.max(ari(ds.labels.as_ref().unwrap(), &res.assignments));
        }
        assert!(best > 0.9, "kernel k-means should separate rings, ARI={best}");
    }

    #[test]
    fn weighted_points_pull_centers() {
        // Two clusters of equal size; weighting one point massively should
        // still produce a valid result (smoke + invariants).
        let mut rng = Rng::seeded(45);
        let ds = blobs(&SyntheticSpec::new(100, 2, 2).with_separation(6.0), &mut rng);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 10.0 });
        let mut w = vec![1.0; ds.n];
        w[0] = 50.0;
        let cfg = FullBatchConfig { k: 2, max_iters: 20, weights: Some(w), ..Default::default() };
        let res = FullBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
        assert_eq!(res.assignments.len(), ds.n);
        assert!(res.objective.is_finite());
    }

    #[test]
    fn k_equals_one() {
        let mut rng = Rng::seeded(46);
        let ds = blobs(&SyntheticSpec::new(60, 2, 2), &mut rng);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 4.0 });
        let cfg = FullBatchConfig { k: 1, max_iters: 5, ..Default::default() };
        let res = FullBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
        assert!(res.assignments.iter().all(|&a| a == 0));
    }
}
