//! Objective evaluation: `f_A(C) = (1/|A|)·Σ_{x∈A} min_j Δ(x, C^j)` and the
//! weighted generalization `f_A(C) = Σ w_x·f_x / Σ w_x` (paper footnote 1).

use super::backend::{argmin_rows_into, AssignBackend};
use super::state::CenterWindow;
use crate::kernels::KernelProvider;

/// Assign a set of points to truncated centers; returns (assignments,
/// min squared distances). Runs through the given backend in slabs of
/// `slab` points so the XLA backend can reuse its fixed-batch executable.
/// The distance matrix and per-slab argmin buffers are hoisted out of the
/// slab loop and reused across it.
pub fn assign_points(
    gram: &dyn KernelProvider,
    centers: &mut [CenterWindow],
    points: &[usize],
    backend: &mut dyn AssignBackend,
    slab: usize,
) -> (Vec<usize>, Vec<f64>) {
    let k = centers.len();
    let mut assignments = Vec::with_capacity(points.len());
    let mut dists = Vec::with_capacity(points.len());
    let mut dist = Vec::new();
    let mut a = Vec::new();
    let mut m = Vec::new();
    for chunk in points.chunks(slab.max(1)) {
        backend.distances_into(gram, chunk, centers, &mut dist);
        argmin_rows_into(&dist, k, &mut a, &mut m);
        assignments.extend_from_slice(&a);
        dists.extend_from_slice(&m);
    }
    (assignments, dists)
}

/// Weighted mean of `min_dists` with optional per-point weights aligned to
/// `points` (dataset weights, not batch multiplicity).
pub fn weighted_mean(
    points: &[usize],
    min_dists: &[f64],
    weights: Option<&[f64]>,
) -> f64 {
    assert_eq!(points.len(), min_dists.len());
    if points.is_empty() {
        return 0.0;
    }
    match weights {
        None => min_dists.iter().sum::<f64>() / points.len() as f64,
        Some(ws) => {
            let mut num = 0.0;
            let mut den = 0.0;
            for (&p, &d) in points.iter().zip(min_dists.iter()) {
                let w = ws[p];
                num += w * d;
                den += w;
            }
            num / den
        }
    }
}

/// [`weighted_mean`] over the *whole* dataset — `min_dists[x]` is point
/// x's min squared distance — without materializing the identity index
/// vector (8 MB of indices at n = 10⁶). Identical accumulation order to
/// `weighted_mean(&(0..n).collect::<Vec<_>>(), …)`.
pub fn weighted_mean_all(min_dists: &[f64], weights: Option<&[f64]>) -> f64 {
    if min_dists.is_empty() {
        return 0.0;
    }
    match weights {
        None => min_dists.iter().sum::<f64>() / min_dists.len() as f64,
        Some(ws) => {
            let mut num = 0.0;
            let mut den = 0.0;
            for (&w, &d) in ws.iter().zip(min_dists.iter()) {
                num += w * d;
                den += w;
            }
            num / den
        }
    }
}

/// Full-dataset objective `f_X(Ĉ)` plus final assignments.
pub fn evaluate_full(
    gram: &dyn KernelProvider,
    centers: &mut [CenterWindow],
    backend: &mut dyn AssignBackend,
    weights: Option<&[f64]>,
) -> (Vec<usize>, f64) {
    let n = gram.n();
    let points: Vec<usize> = (0..n).collect();
    let (assignments, dists) = assign_points(gram, centers, &points, backend, 4096);
    let obj = weighted_mean_all(&dists, weights);
    (assignments, obj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};
    use crate::kernels::{Gram, KernelFunction};
    use crate::kkmeans::backend::NativeBackend;
    use crate::util::rng::Rng;

    #[test]
    fn weighted_mean_reduces_to_mean() {
        let pts = [0, 1, 2];
        let d = [1.0, 2.0, 3.0];
        assert_eq!(weighted_mean(&pts, &d, None), 2.0);
        let w = [1.0, 1.0, 1.0];
        assert!((weighted_mean(&pts, &d, Some(&w)) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_mean_all_matches_indexed_form() {
        let d = [0.25, 3.0, 1.5, 0.0, 7.0];
        let w = [1.0, 2.0, 0.5, 3.0, 1.0];
        let pts: Vec<usize> = (0..d.len()).collect();
        assert_eq!(
            weighted_mean_all(&d, None).to_bits(),
            weighted_mean(&pts, &d, None).to_bits()
        );
        assert_eq!(
            weighted_mean_all(&d, Some(&w)).to_bits(),
            weighted_mean(&pts, &d, Some(&w)).to_bits()
        );
        assert_eq!(weighted_mean_all(&[], None), 0.0);
    }

    #[test]
    fn weighted_mean_respects_weights() {
        let pts = [0, 1];
        let d = [0.0, 10.0];
        let w = [3.0, 1.0];
        assert!((weighted_mean(&pts, &d, Some(&w)) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn slab_size_does_not_change_result() {
        let mut rng = Rng::seeded(17);
        let ds = blobs(&SyntheticSpec::new(100, 3, 2), &mut rng);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 5.0 });
        let mut centers = vec![CenterWindow::new(0, 30), CenterWindow::new(50, 30)];
        centers[0].apply_update(0.5, &[1, 2, 3], None);
        let pts: Vec<usize> = (0..ds.n).collect();
        let mut be = NativeBackend;
        let (a1, d1) = assign_points(&gram, &mut centers, &pts, &mut be, 7);
        let (a2, d2) = assign_points(&gram, &mut centers, &pts, &mut be, 1000);
        assert_eq!(a1, a2);
        for (x, y) in d1.iter().zip(d2.iter()) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn evaluate_full_objective_decreases_with_better_centers() {
        let mut rng = Rng::seeded(19);
        let ds = blobs(
            &SyntheticSpec::new(200, 3, 2).with_std(0.3).with_separation(8.0),
            &mut rng,
        );
        let labels = ds.labels.clone().unwrap();
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 10.0 });
        let mut be = NativeBackend;
        // Bad: both centers the same point. Good: one per blob, updated with
        // same-blob members.
        let mut bad = vec![CenterWindow::new(0, 100), CenterWindow::new(0, 100)];
        let (_, bad_obj) = evaluate_full(&gram, &mut bad, &mut be, None);
        let blob0: Vec<usize> = (0..ds.n).filter(|&i| labels[i] == 0).take(20).collect();
        let blob1: Vec<usize> = (0..ds.n).filter(|&i| labels[i] == 1).take(20).collect();
        let mut good = vec![
            CenterWindow::new(blob0[0], 100),
            CenterWindow::new(blob1[0], 100),
        ];
        good[0].apply_update(0.9, &blob0, None);
        good[1].apply_update(0.9, &blob1, None);
        let (assign, good_obj) = evaluate_full(&gram, &mut good, &mut be, None);
        assert!(good_obj < bad_obj, "good={good_obj} bad={bad_obj}");
        // Good centers should recover the blob structure.
        let agree = (0..ds.n)
            .filter(|&i| (assign[i] == 0) == (labels[i] == 0))
            .count();
        let agree = agree.max(ds.n - agree);
        assert!(agree as f64 / ds.n as f64 > 0.95);
    }
}
