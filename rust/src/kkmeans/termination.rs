//! ε-termination: when has the mini-batch descent provably flattened?
//!
//! The paper terminates when the per-batch improvement
//! `f_B(C_i) − f_B(C_{i+1})` drops below ε, and Theorem 1 bounds the
//! number of such iterations by `O(γ²/ε)` (γ = sup‖φ(x)‖²; γ = 1 for
//! normalized kernels such as the Gaussian). A single batch's improvement
//! is however a *noisy estimate* of the population improvement — one
//! lucky batch can fire the stop long before the descent has actually
//! flattened. Following the windowed-estimator viewpoint of Schwartzman's
//! O(d/ε) analysis (arXiv:2304.00419), [`TerminationMode::Confidence`]
//! tracks the last `w` improvements in a [`VarianceTracker`] and stops
//! only when the *upper confidence bound* `mean + z·sem` falls below ε —
//! the estimator says, with the prescribed confidence, that the expected
//! per-iteration improvement is now below ε.
//!
//! Every call to [`EpsilonStopper::observe`] records a
//! [`TerminationDecision`], so the full decision sequence rides along in
//! [`super::FitResult::decisions`] (and from there into
//! `coordinator::experiment::RunOutcome`) — replayable and testable:
//! feeding the recorded improvements back through a fresh stopper must
//! reproduce the recorded decisions bit-for-bit.

use std::collections::VecDeque;

/// Default window width `w` for [`TerminationMode::Confidence`].
pub const DEFAULT_WINDOW: usize = 8;

/// Default confidence multiplier `z` (≈ 97.7% one-sided normal).
pub const DEFAULT_CONFIDENCE_Z: f64 = 2.0;

/// How `--epsilon` is interpreted.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TerminationMode {
    /// Legacy rule: stop the first time a single batch's improvement is
    /// below ε. Exact transcription of the pre-schedule-era loop (and of
    /// the full-batch `prev_obj − obj < ε` rule), kept for bit-pinned
    /// equivalence tests and full-batch runs where the improvement is not
    /// a noisy estimate.
    SingleBatch,
    /// Windowed estimator with a confidence bound: stop when
    /// `mean(last w improvements) + z·sem < ε`. Never fires on iteration
    /// 0. The default for mini-batch `--epsilon` runs.
    Confidence {
        /// Window width `w ≥ 1` (number of recent improvements kept).
        window: usize,
        /// Confidence multiplier `z ≥ 0` on the standard error.
        z: f64,
    },
}

impl Default for TerminationMode {
    fn default() -> Self {
        TerminationMode::Confidence { window: DEFAULT_WINDOW, z: DEFAULT_CONFIDENCE_Z }
    }
}

/// One recorded stop-rule evaluation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TerminationDecision {
    /// 0-based iteration the decision was made at.
    pub iteration: usize,
    /// The raw batch improvement `f_B(C_i) − f_B(C_{i+1})` observed.
    pub improvement: f64,
    /// The estimator's point estimate of the expected improvement.
    pub estimate: f64,
    /// The upper confidence bound compared against ε.
    pub upper: f64,
    /// Whether the rule fired (the fit stopped after this iteration).
    pub stop: bool,
}

/// Sliding-window mean/variance over the most recent improvements.
///
/// Values are kept explicitly (the window is small) so mean and sample
/// variance are computed exactly, with no accumulated drift — important
/// because the decision sequence is bit-pinned by tests.
#[derive(Clone, Debug)]
pub struct VarianceTracker {
    window: usize,
    values: VecDeque<f64>,
}

impl VarianceTracker {
    /// Track the last `window` values (clamped to ≥ 1).
    pub fn new(window: usize) -> Self {
        let window = window.max(1);
        VarianceTracker { window, values: VecDeque::with_capacity(window) }
    }

    /// Window width.
    pub fn window(&self) -> usize {
        self.window
    }

    /// Push a value, evicting the oldest when full.
    pub fn push(&mut self, v: f64) {
        if self.values.len() == self.window {
            self.values.pop_front();
        }
        self.values.push_back(v);
    }

    /// Number of values currently held.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// True when no values have been observed yet.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Window mean; NaN on an empty window.
    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    /// Sample variance (n−1 denominator); 0 with fewer than two values.
    pub fn variance(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        self.values.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (n - 1) as f64
    }

    /// Sample standard deviation; 0 with fewer than two values.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean `std/√n`; 0 with fewer than two values.
    pub fn sem(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        self.std() / (self.values.len() as f64).sqrt()
    }
}

/// The stop rule driven by the fit loops: feed each iteration's batch
/// improvement to [`EpsilonStopper::observe`]; it answers "stop now?" and
/// records the decision.
#[derive(Clone, Debug)]
pub struct EpsilonStopper {
    epsilon: f64,
    mode: TerminationMode,
    tracker: VarianceTracker,
    decisions: Vec<TerminationDecision>,
}

impl EpsilonStopper {
    /// Build a stopper for threshold ε under the given mode.
    pub fn new(epsilon: f64, mode: TerminationMode) -> Self {
        let window = match mode {
            TerminationMode::SingleBatch => 1,
            TerminationMode::Confidence { window, .. } => window,
        };
        EpsilonStopper {
            epsilon,
            mode,
            tracker: VarianceTracker::new(window),
            decisions: Vec::new(),
        }
    }

    /// Observe iteration `iteration`'s improvement; returns true when the
    /// fit should stop. Deterministic in the observation sequence alone —
    /// no RNG, no thread-count dependence.
    pub fn observe(&mut self, iteration: usize, improvement: f64) -> bool {
        let (estimate, upper, stop) = match self.mode {
            TerminationMode::SingleBatch => {
                (improvement, improvement, improvement < self.epsilon)
            }
            TerminationMode::Confidence { z, .. } => {
                self.tracker.push(improvement);
                let estimate = self.tracker.mean();
                let upper = estimate + z * self.tracker.sem();
                // Needs at least two observations (or a full width-1
                // window) before it may fire — so never on iteration 0.
                let enough = self.tracker.len() >= self.tracker.window().min(2);
                (estimate, upper, iteration >= 1 && enough && upper < self.epsilon)
            }
        };
        self.decisions.push(TerminationDecision { iteration, improvement, estimate, upper, stop });
        stop
    }

    /// Decisions recorded so far, one per observed iteration.
    pub fn decisions(&self) -> &[TerminationDecision] {
        &self.decisions
    }

    /// Consume the stopper, yielding the recorded decision sequence.
    pub fn into_decisions(self) -> Vec<TerminationDecision> {
        self.decisions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracker_empty_window() {
        let t = VarianceTracker::new(4);
        assert!(t.is_empty());
        assert_eq!(t.len(), 0);
        assert!(t.mean().is_nan());
        assert_eq!(t.variance(), 0.0);
        assert_eq!(t.sem(), 0.0);
    }

    #[test]
    fn tracker_single_sample() {
        // k = 1: one observation — mean is the value, spread is zero.
        let mut t = VarianceTracker::new(4);
        t.push(3.5);
        assert_eq!(t.len(), 1);
        assert_eq!(t.mean(), 3.5);
        assert_eq!(t.variance(), 0.0);
        assert_eq!(t.std(), 0.0);
        assert_eq!(t.sem(), 0.0);
    }

    #[test]
    fn tracker_zero_variance() {
        let mut t = VarianceTracker::new(4);
        for _ in 0..10 {
            t.push(2.0);
        }
        assert_eq!(t.len(), 4);
        assert_eq!(t.mean(), 2.0);
        assert_eq!(t.variance(), 0.0);
        assert_eq!(t.sem(), 0.0);
    }

    #[test]
    fn tracker_evicts_oldest_and_matches_exact_moments() {
        let mut t = VarianceTracker::new(3);
        for v in [10.0, 1.0, 2.0, 3.0] {
            t.push(v);
        }
        // Window is now [1, 2, 3].
        assert_eq!(t.len(), 3);
        assert!((t.mean() - 2.0).abs() < 1e-15);
        assert!((t.variance() - 1.0).abs() < 1e-15);
        assert!((t.sem() - (1.0f64 / 3.0).sqrt()).abs() < 1e-15);
    }

    #[test]
    fn tracker_width_clamped_to_one() {
        let mut t = VarianceTracker::new(0);
        t.push(1.0);
        t.push(5.0);
        assert_eq!(t.len(), 1);
        assert_eq!(t.mean(), 5.0);
    }

    #[test]
    fn single_batch_matches_legacy_rule() {
        let mut s = EpsilonStopper::new(1e-3, TerminationMode::SingleBatch);
        assert!(!s.observe(0, 0.5));
        assert!(!s.observe(1, 1e-3)); // not strictly below
        assert!(s.observe(2, 0.5e-3));
        let d = s.decisions();
        assert_eq!(d.len(), 3);
        assert!(d[2].stop && !d[1].stop && !d[0].stop);
        assert_eq!(d[2].estimate, d[2].improvement);
        assert_eq!(d[2].upper, d[2].improvement);
    }

    #[test]
    fn confidence_never_fires_on_iteration_zero() {
        let mut s = EpsilonStopper::new(f64::INFINITY, TerminationMode::default());
        assert!(!s.observe(0, 0.0), "must not stop on iteration 0 even with ε = ∞");
        assert!(!s.decisions()[0].stop);
        assert!(s.observe(1, 0.0));
    }

    #[test]
    fn confidence_waits_for_upper_bound() {
        // Noisy positive improvements keep the upper bound above ε; only
        // once the window flattens near zero does the rule fire.
        let mode = TerminationMode::Confidence { window: 4, z: 2.0 };
        let mut s = EpsilonStopper::new(1e-2, mode);
        let mut stopped_at = None;
        let seq = [1.0, 0.8, 0.5, 0.3, 0.2, 0.1, 1e-3, 1e-3, 1e-3, 1e-3, 1e-3];
        for (i, &imp) in seq.iter().enumerate() {
            if s.observe(i, imp) {
                stopped_at = Some(i);
                break;
            }
        }
        let at = stopped_at.expect("should eventually stop");
        // Needs the window to flush the large early improvements first.
        assert!(at >= 8, "stopped too early at {at}");
        let d = *s.decisions().last().unwrap();
        assert!(d.stop && d.upper < 1e-2);
    }

    #[test]
    fn confidence_single_value_window_behaves_like_single_batch_after_warmup() {
        let mode = TerminationMode::Confidence { window: 1, z: 2.0 };
        let mut s = EpsilonStopper::new(1e-3, mode);
        assert!(!s.observe(0, 1e-9), "iteration 0 is always a continue");
        assert!(s.observe(1, 1e-9));
    }

    #[test]
    fn zero_variance_window_fires_exactly_at_threshold_crossing() {
        let mode = TerminationMode::Confidence { window: 3, z: 2.0 };
        let mut s = EpsilonStopper::new(1e-3, mode);
        assert!(!s.observe(0, 5e-4));
        // Second identical observation: mean 5e-4, sem 0 ⇒ upper 5e-4 < ε.
        assert!(s.observe(1, 5e-4));
    }

    #[test]
    fn replaying_recorded_improvements_reproduces_decisions() {
        let mode = TerminationMode::Confidence { window: 5, z: 1.5 };
        let mut s = EpsilonStopper::new(2e-2, mode);
        let seq = [0.9, 0.4, 0.2, 0.05, 0.01, 0.012, 0.009, 0.011, 0.01, 0.01, 0.01];
        for (i, &imp) in seq.iter().enumerate() {
            if s.observe(i, imp) {
                break;
            }
        }
        let recorded = s.into_decisions();
        let mut replay = EpsilonStopper::new(2e-2, mode);
        for d in &recorded {
            let stop = replay.observe(d.iteration, d.improvement);
            assert_eq!(stop, d.stop);
        }
        assert_eq!(replay.decisions(), recorded.as_slice());
    }
}
