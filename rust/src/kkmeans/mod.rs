//! Kernel k-means algorithms — the paper's core contribution.
//!
//! Three algorithms over a shared [`crate::kernels::KernelProvider`]
//! substrate — every `fit` accepts `&dyn KernelProvider`, so the same
//! algorithm runs against an on-the-fly kernel, a materialized n×n table,
//! or the streaming tile-LRU-cached provider
//! ([`crate::kernels::CachedGram`]) without code changes:
//!
//! * [`FullBatchKernelKMeans`] — Lloyd's algorithm in feature space
//!   (Dhillon et al. 2004), `O(n²)` per iteration. The baseline.
//! * [`MiniBatchKernelKMeans`] — the paper's **Algorithm 1**: mini-batch
//!   updates with the recursive distance rule, maintaining `⟨φ(x), C_j⟩`
//!   by *lazy, generation-stamped* dynamic programming
//!   ([`state::LazyAssignState`]) — an iteration touches only the `b`
//!   sampled points (`Õ(kb²)` in the paper's regime, independent of `n`);
//!   the full dataset is visited once, in the finalize pass.
//! * [`TruncatedMiniBatchKernelKMeans`] — the paper's **Algorithm 2**:
//!   centers are *truncated* to a sliding window of the most recent ≈τ
//!   support points (Section 4.1), giving `Õ(kb²)` per iteration with no
//!   dependence on `n`. The assignment step runs through an
//!   [`AssignBackend`] — pure-Rust native, or the AOT-compiled
//!   JAX/Pallas graph via [`crate::runtime::XlaBackend`].
//!
//! Plus the shared machinery: kernel k-means++ initialization ([`init`]),
//! the β/sklearn learning-rate policies ([`learning_rate`]), the
//! sliding-window center state ([`state`]), and objective evaluation
//! ([`objective`]).

pub mod backend;
pub mod full_batch;
pub mod init;
pub mod learning_rate;
pub mod minibatch;
pub mod objective;
pub mod predict;
pub mod schedule;
pub mod state;
pub mod termination;
pub mod truncated;

pub use backend::{AssignBackend, NativeBackend};
pub use full_batch::{FullBatchConfig, FullBatchKernelKMeans};
pub use learning_rate::LearningRate;
pub use minibatch::{MiniBatchConfig, MiniBatchKernelKMeans};
pub use predict::{KernelKMeansModel, StreamingKernelKMeans};
pub use schedule::{BatchSchedule, FixedSchedule, NestedSchedule, ScheduleSpec};
pub use state::{CenterWindow, LazyAssignState};
pub use termination::{
    EpsilonStopper, TerminationDecision, TerminationMode, VarianceTracker,
};
pub use truncated::{TrainSnapshot, TruncatedConfig, TruncatedFit, TruncatedMiniBatchKernelKMeans};

use crate::util::timing::Profiler;

/// Result of fitting any of the clustering algorithms.
#[derive(Clone, Debug)]
pub struct FitResult {
    /// Final hard assignment of every dataset point.
    pub assignments: Vec<usize>,
    /// Final full-dataset objective `f_X(C)` (mean squared feature-space
    /// distance to the closest center; weighted mean in the weighted case).
    pub objective: f64,
    /// `f_{B_i}(C_i)` per iteration (batch objective before the update) —
    /// for mini-batch algorithms; full-batch records `f_X(C_i)`.
    pub history: Vec<f64>,
    /// Iterations executed.
    pub iterations: usize,
    /// True if the ε early-stopping condition fired (vs. hitting max_iters).
    pub converged: bool,
    /// The ε stop rule's decision sequence, one entry per evaluated
    /// iteration (empty when `epsilon` is `None` or the algorithm has no
    /// stop rule). Replayable: feeding the recorded improvements back
    /// through a fresh [`EpsilonStopper`] reproduces the decisions.
    pub decisions: Vec<TerminationDecision>,
    /// Per-phase timing breakdown.
    pub profiler: Profiler,
}

/// How initial centers are chosen. Every option yields centers that are
/// convex combinations of X (single dataset points), as Algorithm 1 requires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Init {
    /// k distinct points uniformly at random.
    Uniform,
    /// Kernel k-means++ (Arthur & Vassilvitskii 2007 in feature space):
    /// yields the `O(log k)` expected approximation of Theorem 1(3).
    KMeansPlusPlus,
    /// Kernel k-means++ run on a uniform subsample of this size (init cost
    /// `O(sample·k)` instead of `O(n·k)`); the paper's "any reasonable
    /// initialization" covers this.
    KMeansPlusPlusOnSample(usize),
}

impl Default for Init {
    fn default() -> Self {
        Init::KMeansPlusPlus
    }
}
