//! **Algorithm 2** — truncated mini-batch kernel k-means (paper §4.1).
//!
//! The headline algorithm: each center is a [`CenterWindow`] over at most
//! τ+b recent support points, so one iteration costs `O(k(τ+b)²) = Õ(kb²)`
//! — *independent of n*. With Lemma 3's `τ = ⌈b·ln²(28γ/ε)⌉` the truncated
//! centers stay within ε/28 of the exact ones, and Theorem 1 gives
//! termination in `O(γ²/ε)` iterations for
//! `b = Ω(max{γ⁴,γ²}·ε⁻²·log²(γn/ε))`.
//!
//! The assignment hot-spot runs through an [`AssignBackend`]; pass
//! [`crate::runtime::XlaBackend`] to execute the AOT-compiled JAX/Pallas
//! graph, or [`NativeBackend`] for the pure-Rust path — which serves the
//! `K(B, S)·w` contraction through the cache-tiled engine in
//! [`crate::kernels::Gram::weighted_cross_into`] (DESIGN.md §5).

use super::backend::{argmin_rows_into, AssignBackend, NativeBackend};
use super::init::choose_centers;
use super::learning_rate::{LearningRate, RateState};
use super::schedule::ScheduleSpec;
use super::state::{CenterWindow, WindowState};
use super::termination::{EpsilonStopper, TerminationMode};
use super::{FitResult, Init};
use crate::bail;
use crate::kernels::KernelProvider;
use crate::util::error::Result;
use crate::util::rng::Rng;
use crate::util::timing::{Profiler, Stopwatch};

/// Configuration for [`TruncatedMiniBatchKernelKMeans`] (Algorithm 2).
#[derive(Clone, Debug)]
pub struct TruncatedConfig {
    /// Number of clusters.
    pub k: usize,
    /// Batch size `b` (uniform with repetitions). Under a nested schedule
    /// this is the starting size `b₀`.
    pub batch_size: usize,
    /// Batch schedule: fixed-b (the paper's protocol) or nested geometric
    /// growth with deterministic sample reuse.
    pub schedule: ScheduleSpec,
    /// Truncation parameter τ: target number of support points per center.
    /// The paper sweeps τ ∈ {50, 100, 200, 300}; `usize::MAX` disables
    /// truncation (Algorithm 1 semantics, explicit representation).
    pub tau: usize,
    /// Iteration budget.
    pub max_iters: usize,
    /// Early-stopping ε on batch improvement; `None` = fixed iterations.
    pub epsilon: Option<f64>,
    /// How ε is interpreted (windowed confidence estimator by default;
    /// [`TerminationMode::SingleBatch`] for the legacy one-batch rule).
    pub termination: TerminationMode,
    /// Learning-rate schedule for the center updates.
    pub learning_rate: LearningRate,
    /// Center initialization method.
    pub init: Init,
    /// Optional per-point weights (weighted variant, footnote 1).
    pub weights: Option<Vec<f64>>,
}

impl Default for TruncatedConfig {
    fn default() -> Self {
        TruncatedConfig {
            k: 2,
            batch_size: 1024,
            schedule: ScheduleSpec::Fixed,
            tau: 200,
            max_iters: 200,
            epsilon: None,
            termination: TerminationMode::default(),
            learning_rate: LearningRate::Beta,
            init: Init::default(),
            weights: None,
        }
    }
}

impl TruncatedConfig {
    /// τ from Lemma 3 for the given γ and ε.
    pub fn with_lemma3_tau(mut self, gamma: f64, epsilon: f64) -> Self {
        self.tau = CenterWindow::lemma3_tau(self.batch_size, gamma, epsilon);
        self
    }
}

/// Mid-fit state of Algorithm 2 captured at an iteration boundary —
/// everything the loop needs to continue **bit-identically** to an
/// uninterrupted run (DESIGN.md §12). Serialized as the kind-`train`
/// artifact by [`crate::serve::format`]; rotated on disk by
/// [`crate::coordinator::checkpoint`]. Opaque outside the crate.
#[derive(Clone)]
pub struct TrainSnapshot {
    /// Iterations completed; the resumed loop starts here.
    pub(crate) next_iter: usize,
    /// Fit RNG at the boundary (Xoshiro words + Box–Muller cache).
    pub(crate) rng: Rng,
    /// Owned state of every center window.
    pub(crate) windows: Vec<WindowState>,
    /// Learning-rate schedule kind and per-center counters.
    pub(crate) rate_kind: LearningRate,
    pub(crate) rate_counts: Vec<f64>,
    /// Pre-update batch objectives of every completed iteration.
    pub(crate) history: Vec<f64>,
    /// Stopper replay log: `(iteration, improvement)` per recorded
    /// decision. Replaying these through a fresh [`EpsilonStopper`]
    /// rebuilds its windowed variance tracker bit-identically (pinned by
    /// `termination::tests::replaying_recorded_improvements_reproduces_decisions`).
    pub(crate) improvements: Vec<(u32, f64)>,
    /// The last completed iteration's batch — the carry prefix a resumed
    /// [`super::schedule::NestedSchedule`] needs.
    pub(crate) prev_batch: Vec<usize>,
}

impl TrainSnapshot {
    /// Iterations completed when this snapshot was taken.
    pub fn iterations(&self) -> usize {
        self.next_iter
    }
}

/// Detailed fit output: shared [`FitResult`] plus the final center windows
/// (for inspection, warm restarts, or serving).
pub struct TruncatedFit {
    /// The shared fit output (assignments, objective, history, profiler).
    pub result: FitResult,
    /// Final truncated center windows.
    pub centers: Vec<CenterWindow>,
}

/// Algorithm 2 runner.
pub struct TruncatedMiniBatchKernelKMeans {
    cfg: TruncatedConfig,
}

impl TruncatedMiniBatchKernelKMeans {
    /// Wrap a configuration.
    pub fn new(cfg: TruncatedConfig) -> Self {
        TruncatedMiniBatchKernelKMeans { cfg }
    }

    /// Fit with the native backend.
    pub fn fit(&self, gram: &dyn KernelProvider, rng: &mut Rng) -> FitResult {
        self.fit_with_backend(gram, &mut NativeBackend, rng).result
    }

    /// Fit with an explicit assignment backend (native or XLA).
    pub fn fit_with_backend(
        &self,
        gram: &dyn KernelProvider,
        backend: &mut dyn AssignBackend,
        rng: &mut Rng,
    ) -> TruncatedFit {
        self.fit_with_backend_resumable(gram, backend, rng, None, 0, &mut |_| Ok(()))
            .expect("fit without a checkpoint sink is infallible")
    }

    /// [`fit_with_backend`](Self::fit_with_backend) with crash-recovery
    /// support (DESIGN.md §12): optionally start from a restored
    /// [`TrainSnapshot`] instead of initializing, and hand a snapshot to
    /// `sink` after every `checkpoint_every`-th completed iteration
    /// (`0` = never). A resumed run replays the exact loop the
    /// uninterrupted run would have executed — same RNG draws, same
    /// batches (the schedule's carry prefix is restored), same stopper
    /// decisions — so final assignments, objective, and artifact bytes
    /// are identical. A `sink` error aborts the fit (durability failures
    /// must surface, not silently stop checkpointing).
    pub fn fit_with_backend_resumable(
        &self,
        gram: &dyn KernelProvider,
        backend: &mut dyn AssignBackend,
        rng: &mut Rng,
        resume: Option<TrainSnapshot>,
        checkpoint_every: usize,
        sink: &mut dyn FnMut(&TrainSnapshot) -> Result<()>,
    ) -> Result<TruncatedFit> {
        let n = gram.n();
        let k = self.cfg.k;
        assert!(k >= 1 && k <= n);
        let weights = self.cfg.weights.as_deref();
        let mut prof = Profiler::new();
        let mut schedule = self.cfg.schedule.build(self.cfg.batch_size);
        let b_max = schedule.max_batch(n);
        let mut stopper = self
            .cfg
            .epsilon
            .map(|eps| EpsilonStopper::new(eps, self.cfg.termination));

        let start_iter;
        let mut centers: Vec<CenterWindow>;
        let mut rate;
        let mut history;
        match resume {
            None => {
                // ---- init --------------------------------------------------
                let sw = Stopwatch::start();
                let seeds = choose_centers(gram, k, self.cfg.init, rng);
                centers = seeds
                    .iter()
                    .map(|&s| CenterWindow::new(s, self.cfg.tau))
                    .collect();
                rate = RateState::new(self.cfg.learning_rate, k);
                prof.add("init", sw.secs());
                history = Vec::new();
                start_iter = 0;
            }
            Some(snap) => {
                // ---- resume: restore the checkpointed loop state -----------
                let sw = Stopwatch::start();
                if snap.windows.len() != k {
                    bail!(
                        "checkpoint has {} centers but the run is configured \
                         for k={k}",
                        snap.windows.len()
                    );
                }
                if snap.rate_counts.len() != k {
                    bail!(
                        "checkpoint has {} learning-rate counters for k={k} \
                         centers",
                        snap.rate_counts.len()
                    );
                }
                if snap.rate_kind.name() != self.cfg.learning_rate.name() {
                    bail!(
                        "checkpoint used the {:?} learning-rate schedule but \
                         the run is configured for {:?}",
                        snap.rate_kind.name(),
                        self.cfg.learning_rate.name()
                    );
                }
                if snap.next_iter > self.cfg.max_iters {
                    bail!(
                        "checkpoint is at iteration {} but the run is \
                         configured for max_iters={}",
                        snap.next_iter,
                        self.cfg.max_iters
                    );
                }
                *rng = snap.rng;
                centers = snap.windows.into_iter().map(CenterWindow::from_state).collect();
                rate = RateState::from_parts(snap.rate_kind, snap.rate_counts);
                history = snap.history;
                if let Some(st) = stopper.as_mut() {
                    for &(it, imp) in &snap.improvements {
                        // None of the replayed decisions stopped (a stopped
                        // run is never checkpointed past its last iteration),
                        // so the return value is vacuous here.
                        let _ = st.observe(it as usize, imp);
                    }
                }
                schedule.restore_prev(&snap.prev_batch);
                start_iter = snap.next_iter;
                prof.add("resume", sw.secs());
            }
        }

        let mut iterations = start_iter;
        let mut converged = false;

        // Buffers hoisted out of the iteration loop (§Perf): the distance
        // matrix, argmin outputs, member lists, and per-center weight
        // staging are reused across iterations.
        let mut batch: Vec<usize> = Vec::with_capacity(b_max);
        let mut dist: Vec<f64> = Vec::new();
        let mut assign: Vec<usize> = Vec::with_capacity(b_max);
        let mut mins: Vec<f64> = Vec::with_capacity(b_max);
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); k];
        let mut pw: Vec<f64> = Vec::new();

        for iter in start_iter..self.cfg.max_iters {
            iterations += 1;
            // ---- sample + assign (the Õ(kb²) hot path) ----------------------
            let sw = Stopwatch::start();
            schedule.next_batch(iter, n, rng, &mut batch);
            let b = batch.len();
            backend.distances_into(gram, &batch, &mut centers, &mut dist);
            argmin_rows_into(&dist, k, &mut assign, &mut mins);
            let f_before = super::objective::weighted_mean(&batch, &mins, weights);
            history.push(f_before);
            prof.add("assign", sw.secs());

            // ---- update windows ---------------------------------------------
            let sw = Stopwatch::start();
            for m in members.iter_mut() {
                m.clear();
            }
            for (r, &j) in assign.iter().enumerate() {
                members[j].push(batch[r]);
            }
            for j in 0..k {
                let alpha = rate.alpha(j, members[j].len(), b);
                if alpha == 0.0 {
                    continue;
                }
                let pwj: Option<&[f64]> = match weights {
                    None => None,
                    Some(w) => {
                        pw.clear();
                        pw.extend(members[j].iter().map(|&y| w[y]));
                        Some(pw.as_slice())
                    }
                };
                // Incremental ⟨Ĉ,Ĉ⟩ maintenance (§Perf): O(M·b_j) instead of
                // the O(M²) recompute the next assignment would pay.
                centers[j].apply_update_cc(alpha, &members[j], pwj, gram);
            }
            prof.add("update", sw.secs());

            // ---- early stopping: f_B(Ĉ_i) − f_B(Ĉ_{i+1}) < ε ----------------
            if let Some(stopper) = stopper.as_mut() {
                let sw = Stopwatch::start();
                backend.distances_into(gram, &batch, &mut centers, &mut dist);
                argmin_rows_into(&dist, k, &mut assign, &mut mins);
                let f_after = super::objective::weighted_mean(&batch, &mins, weights);
                prof.add("stopping", sw.secs());
                if stopper.observe(iter, f_before - f_after) {
                    converged = true;
                    break;
                }
            }

            // ---- periodic durable checkpoint --------------------------------
            // Captured after the stopper so a converged run never re-snapshots,
            // and skipped on the final iteration (the finished artifact is the
            // durable output there).
            if checkpoint_every > 0
                && (iter + 1) % checkpoint_every == 0
                && iter + 1 < self.cfg.max_iters
            {
                let sw = Stopwatch::start();
                let snap = TrainSnapshot {
                    next_iter: iter + 1,
                    rng: rng.clone(),
                    windows: centers.iter().map(CenterWindow::owned_state).collect(),
                    rate_kind: rate.kind(),
                    rate_counts: rate.counts().to_vec(),
                    history: history.clone(),
                    improvements: stopper
                        .as_ref()
                        .map(|s| {
                            s.decisions()
                                .iter()
                                .map(|d| (d.iteration as u32, d.improvement))
                                .collect()
                        })
                        .unwrap_or_default(),
                    prev_batch: batch.clone(),
                };
                sink(&snap)?;
                prof.add("checkpoint", sw.secs());
            }
        }

        // ---- finalize -------------------------------------------------------
        let sw = Stopwatch::start();
        let (assignments, objective) =
            super::objective::evaluate_full(gram, &mut centers, backend, weights);
        prof.add("finalize", sw.secs());

        Ok(TruncatedFit {
            result: FitResult {
                assignments,
                objective,
                history,
                iterations,
                converged,
                decisions: stopper.map(EpsilonStopper::into_decisions).unwrap_or_default(),
                profiler: prof,
            },
            centers,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, rings, SyntheticSpec};
    use crate::kernels::{Gram, KernelFunction};
    use crate::metrics::ari;

    fn fixture(n: usize) -> crate::data::Dataset {
        let mut rng = Rng::seeded(7);
        blobs(
            &SyntheticSpec::new(n, 4, 3).with_std(0.4).with_separation(7.0),
            &mut rng,
        )
    }

    #[test]
    fn recovers_blobs() {
        let ds = fixture(800);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 20.0 });
        let cfg = TruncatedConfig {
            k: 3,
            batch_size: 128,
            tau: 100,
            max_iters: 60,
            ..Default::default()
        };
        let mut rng = Rng::seeded(1);
        let res = TruncatedMiniBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
        let score = ari(ds.labels.as_ref().unwrap(), &res.assignments);
        assert!(score > 0.9, "ARI={score}");
    }

    #[test]
    fn separates_rings() {
        // Heat kernel: affinity diffuses within each ring and not across
        // (see full_batch tests for why raw knn is too sparse here).
        let mut rng = Rng::seeded(2);
        let ds = rings(900, 2, 2, 0.04, &mut rng);
        let gram = crate::kernels::graph::heat_kernel(&ds, 10, 500.0);
        let cfg = TruncatedConfig {
            k: 2,
            batch_size: 256,
            tau: 200,
            max_iters: 80,
            ..Default::default()
        };
        let mut best = 0.0f64;
        for seed in 0..5 {
            let mut r = Rng::seeded(seed);
            let res = TruncatedMiniBatchKernelKMeans::new(cfg.clone()).fit(&gram, &mut r);
            best = best.max(ari(ds.labels.as_ref().unwrap(), &res.assignments));
        }
        assert!(best > 0.85, "ARI={best}");
    }

    #[test]
    fn tiny_tau_still_clusters() {
        // Paper §6: "Surprisingly, this often holds for tiny values of τ
        // (e.g., 50) far below the theoretical threshold".
        let ds = fixture(800);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 20.0 });
        let cfg = TruncatedConfig {
            k: 3,
            batch_size: 128,
            tau: 20,
            max_iters: 60,
            ..Default::default()
        };
        let mut rng = Rng::seeded(3);
        let res = TruncatedMiniBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
        let score = ari(ds.labels.as_ref().unwrap(), &res.assignments);
        assert!(score > 0.8, "ARI={score}");
    }

    #[test]
    fn untruncated_matches_algorithm1_objective_closely() {
        // τ=∞ Algorithm 2 and Algorithm 1 compute the same math through
        // different representations; with the same seed they see identical
        // batches and must produce identical assignments.
        use crate::kkmeans::minibatch::{MiniBatchConfig, MiniBatchKernelKMeans};
        let ds = fixture(300);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 15.0 });
        let base = (3usize, 64usize, 25usize);
        let cfg2 = TruncatedConfig {
            k: base.0,
            batch_size: base.1,
            tau: usize::MAX,
            max_iters: base.2,
            init: Init::Uniform,
            ..Default::default()
        };
        let cfg1 = MiniBatchConfig {
            k: base.0,
            batch_size: base.1,
            max_iters: base.2,
            init: Init::Uniform,
            ..Default::default()
        };
        let mut r1 = Rng::seeded(11);
        let mut r2 = Rng::seeded(11);
        let res1 = MiniBatchKernelKMeans::new(cfg1).fit(&gram, &mut r1);
        let res2 = TruncatedMiniBatchKernelKMeans::new(cfg2).fit(&gram, &mut r2);
        assert_eq!(res1.assignments, res2.assignments);
        assert!((res1.objective - res2.objective).abs() < 1e-8);
        for (a, b) in res1.history.iter().zip(res2.history.iter()) {
            assert!((a - b).abs() < 1e-8, "history diverged: {a} vs {b}");
        }
    }

    #[test]
    fn nested_schedule_recovers_blobs() {
        let ds = fixture(800);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 20.0 });
        let cfg = TruncatedConfig {
            k: 3,
            batch_size: 32,
            schedule: crate::kkmeans::ScheduleSpec::Nested { growth: 2.0 },
            tau: 200,
            max_iters: 40,
            ..Default::default()
        };
        let mut rng = Rng::seeded(9);
        let res = TruncatedMiniBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
        let score = ari(ds.labels.as_ref().unwrap(), &res.assignments);
        assert!(score > 0.9, "ARI={score}");
    }

    #[test]
    fn early_stopping_fires() {
        let ds = fixture(500);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 20.0 });
        let cfg = TruncatedConfig {
            k: 3,
            batch_size: 256,
            tau: 200,
            max_iters: 300,
            epsilon: Some(1e-3),
            ..Default::default()
        };
        let mut rng = Rng::seeded(4);
        let res = TruncatedMiniBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
        assert!(res.converged);
        assert!(res.iterations < 300, "ran {} iterations", res.iterations);
    }

    #[test]
    fn support_size_stays_bounded() {
        let ds = fixture(500);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 20.0 });
        let tau = 50;
        let b = 64;
        let cfg = TruncatedConfig {
            k: 3,
            batch_size: b,
            tau,
            max_iters: 40,
            ..Default::default()
        };
        let mut rng = Rng::seeded(5);
        let fit = TruncatedMiniBatchKernelKMeans::new(cfg)
            .fit_with_backend(&gram, &mut NativeBackend, &mut rng);
        for c in &fit.centers {
            assert!(
                c.support_len() <= tau + b + 1,
                "support={} > τ+b+1",
                c.support_len()
            );
        }
    }

    #[test]
    fn weighted_variant_runs_and_respects_weights() {
        let ds = fixture(300);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 15.0 });
        let w: Vec<f64> = (0..ds.n).map(|i| 1.0 + (i % 3) as f64).collect();
        let cfg = TruncatedConfig {
            k: 3,
            batch_size: 64,
            tau: 100,
            max_iters: 30,
            weights: Some(w),
            ..Default::default()
        };
        let mut rng = Rng::seeded(6);
        let res = TruncatedMiniBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
        assert_eq!(res.assignments.len(), ds.n);
        assert!(res.objective.is_finite());
    }

    #[test]
    fn checkpoint_resume_is_bit_identical() {
        // The crash-recovery property at the in-memory level: a fit resumed
        // from ANY periodic snapshot finishes with bit-identical
        // assignments, objective, history, and iteration count versus the
        // uninterrupted run. Exercises the nested schedule (carry restore)
        // and the ε-stopper (replay restore) on purpose.
        let ds = fixture(500);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 20.0 });
        let cfg = TruncatedConfig {
            k: 3,
            batch_size: 48,
            schedule: crate::kkmeans::ScheduleSpec::Nested { growth: 1.3 },
            tau: 80,
            max_iters: 24,
            epsilon: Some(1e-9),
            ..Default::default()
        };
        let mut r1 = Rng::seeded(12);
        let full = TruncatedMiniBatchKernelKMeans::new(cfg.clone())
            .fit_with_backend(&gram, &mut NativeBackend, &mut r1);
        let mut snaps: Vec<TrainSnapshot> = Vec::new();
        let mut r2 = Rng::seeded(12);
        let replay = TruncatedMiniBatchKernelKMeans::new(cfg.clone())
            .fit_with_backend_resumable(&gram, &mut NativeBackend, &mut r2, None, 5, &mut |s| {
                snaps.push(s.clone());
                Ok(())
            })
            .unwrap();
        assert_eq!(replay.result.assignments, full.result.assignments);
        assert!(!snaps.is_empty(), "the cadence must have produced snapshots");
        for snap in snaps {
            let at = snap.iterations();
            let mut r3 = Rng::seeded(999); // overwritten by the snapshot's RNG
            let resumed = TruncatedMiniBatchKernelKMeans::new(cfg.clone())
                .fit_with_backend_resumable(
                    &gram,
                    &mut NativeBackend,
                    &mut r3,
                    Some(snap),
                    0,
                    &mut |_| Ok(()),
                )
                .unwrap();
            assert_eq!(
                resumed.result.assignments, full.result.assignments,
                "assignments diverged resuming from iteration {at}"
            );
            assert_eq!(
                resumed.result.objective.to_bits(),
                full.result.objective.to_bits(),
                "objective diverged resuming from iteration {at}"
            );
            assert_eq!(resumed.result.history, full.result.history);
            assert_eq!(resumed.result.iterations, full.result.iterations);
            assert_eq!(resumed.result.converged, full.result.converged);
            assert_eq!(resumed.result.decisions.len(), full.result.decisions.len());
        }
    }

    #[test]
    fn resume_rejects_mismatched_config() {
        let ds = fixture(300);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 15.0 });
        let cfg = TruncatedConfig {
            k: 3,
            batch_size: 32,
            tau: 50,
            max_iters: 10,
            ..Default::default()
        };
        let mut snaps = Vec::new();
        let mut rng = Rng::seeded(4);
        TruncatedMiniBatchKernelKMeans::new(cfg.clone())
            .fit_with_backend_resumable(&gram, &mut NativeBackend, &mut rng, None, 4, &mut |s| {
                snaps.push(s.clone());
                Ok(())
            })
            .unwrap();
        let snap = snaps.pop().expect("snapshot");
        let wrong_k = TruncatedConfig { k: 4, ..cfg.clone() };
        let err = TruncatedMiniBatchKernelKMeans::new(wrong_k)
            .fit_with_backend_resumable(
                &gram,
                &mut NativeBackend,
                &mut Rng::seeded(4),
                Some(snap.clone()),
                0,
                &mut |_| Ok(()),
            )
            .unwrap_err();
        assert!(format!("{err}").contains("k="), "{err}");
        let wrong_rate = TruncatedConfig { learning_rate: LearningRate::Sklearn, ..cfg };
        let err = TruncatedMiniBatchKernelKMeans::new(wrong_rate)
            .fit_with_backend_resumable(
                &gram,
                &mut NativeBackend,
                &mut Rng::seeded(4),
                Some(snap),
                0,
                &mut |_| Ok(()),
            )
            .unwrap_err();
        assert!(format!("{err}").contains("learning-rate"), "{err}");
    }

    #[test]
    fn sink_error_aborts_the_fit() {
        let ds = fixture(300);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 15.0 });
        let cfg = TruncatedConfig {
            k: 2,
            batch_size: 32,
            tau: 50,
            max_iters: 20,
            ..Default::default()
        };
        let mut rng = Rng::seeded(8);
        let err = TruncatedMiniBatchKernelKMeans::new(cfg)
            .fit_with_backend_resumable(&gram, &mut NativeBackend, &mut rng, None, 3, &mut |_| {
                crate::bail!("disk full")
            })
            .unwrap_err();
        assert!(format!("{err}").contains("disk full"), "{err}");
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = fixture(300);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 15.0 });
        let cfg = TruncatedConfig {
            k: 3,
            batch_size: 64,
            tau: 80,
            max_iters: 20,
            ..Default::default()
        };
        let mut r1 = Rng::seeded(12);
        let mut r2 = Rng::seeded(12);
        let a = TruncatedMiniBatchKernelKMeans::new(cfg.clone()).fit(&gram, &mut r1);
        let b = TruncatedMiniBatchKernelKMeans::new(cfg).fit(&gram, &mut r2);
        assert_eq!(a.assignments, b.assignments);
        assert_eq!(a.objective, b.objective);
    }
}
