//! Lloyd's algorithm (standard k-means) on raw features.

use super::{assign_to_centers, kmeanspp_features};
use crate::data::Dataset;
use crate::kkmeans::FitResult;
use crate::util::rng::Rng;
use crate::util::timing::{Profiler, Stopwatch};

/// Configuration for [`KMeans`].
#[derive(Clone, Debug)]
pub struct KMeansConfig {
    /// Number of clusters.
    pub k: usize,
    /// Iteration budget.
    pub max_iters: usize,
    /// Stop when no assignment changes (always on) or when the objective
    /// improves by less than ε.
    pub epsilon: Option<f64>,
}

impl Default for KMeansConfig {
    fn default() -> Self {
        KMeansConfig { k: 2, max_iters: 300, epsilon: None }
    }
}

/// Standard k-means (k-means++ init, Lloyd iterations).
pub struct KMeans {
    cfg: KMeansConfig,
}

impl KMeans {
    /// Wrap a configuration.
    pub fn new(cfg: KMeansConfig) -> Self {
        KMeans { cfg }
    }

    /// Run Lloyd's algorithm on raw features.
    pub fn fit(&self, ds: &Dataset, rng: &mut Rng) -> FitResult {
        let k = self.cfg.k;
        let d = ds.d;
        assert!(k >= 1 && k <= ds.n);
        let mut prof = Profiler::new();
        let sw = Stopwatch::start();
        let mut centers = kmeanspp_features(ds, k, rng);
        prof.add("init", sw.secs());

        let mut assignments = vec![0usize; ds.n];
        let mut history = Vec::new();
        let mut iterations = 0;
        let mut converged = false;
        let mut prev_obj = f64::INFINITY;

        for _ in 0..self.cfg.max_iters {
            iterations += 1;
            let sw = Stopwatch::start();
            let (new_assign, obj) = assign_to_centers(ds, &centers, k);
            prof.add("assign", sw.secs());
            history.push(obj);

            let sw = Stopwatch::start();
            // Recompute means.
            let mut sums = vec![0.0f64; k * d];
            let mut counts = vec![0usize; k];
            for (i, &j) in new_assign.iter().enumerate() {
                counts[j] += 1;
                for (s, &v) in sums[j * d..(j + 1) * d].iter_mut().zip(ds.row(i)) {
                    *s += v as f64;
                }
            }
            for j in 0..k {
                if counts[j] > 0 {
                    for s in sums[j * d..(j + 1) * d].iter_mut() {
                        *s /= counts[j] as f64;
                    }
                } else {
                    // Empty cluster: re-seed at a random point.
                    let p = rng.below(ds.n);
                    for (s, &v) in sums[j * d..(j + 1) * d].iter_mut().zip(ds.row(p)) {
                        *s = v as f64;
                    }
                }
            }
            prof.add("update", sw.secs());

            let changed = new_assign
                .iter()
                .zip(assignments.iter())
                .filter(|(a, b)| a != b)
                .count();
            assignments = new_assign;
            centers = sums;

            if changed == 0 && iterations > 1 {
                converged = true;
                break;
            }
            if let Some(eps) = self.cfg.epsilon {
                if prev_obj - obj < eps {
                    converged = true;
                    break;
                }
            }
            prev_obj = obj;
        }

        let sw = Stopwatch::start();
        let (assignments, objective) = assign_to_centers(ds, &centers, k);
        prof.add("finalize", sw.secs());
        FitResult {
            assignments,
            objective,
            history,
            iterations,
            converged,
            decisions: Vec::new(),
            profiler: prof,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, rings, SyntheticSpec};
    use crate::metrics::ari;

    #[test]
    fn recovers_separated_blobs() {
        let mut rng = Rng::seeded(1);
        let ds = blobs(
            &SyntheticSpec::new(400, 3, 3).with_std(0.3).with_separation(8.0),
            &mut rng,
        );
        let res = KMeans::new(KMeansConfig { k: 3, ..Default::default() }).fit(&ds, &mut rng);
        assert!(ari(ds.labels.as_ref().unwrap(), &res.assignments) > 0.95);
        assert!(res.converged);
    }

    #[test]
    fn objective_nonincreasing() {
        let mut rng = Rng::seeded(2);
        let ds = blobs(&SyntheticSpec::new(300, 4, 4), &mut rng);
        let res = KMeans::new(KMeansConfig { k: 4, ..Default::default() }).fit(&ds, &mut rng);
        for w in res.history.windows(2) {
            assert!(w[1] <= w[0] + 1e-9);
        }
    }

    #[test]
    fn fails_on_rings_as_expected() {
        // The motivating negative result: plain k-means cannot separate
        // concentric rings (ARI stays low) — kernel k-means can (see
        // kkmeans::full_batch tests). This contrast is the paper's premise.
        let mut rng = Rng::seeded(3);
        let ds = rings(600, 2, 2, 0.04, &mut rng);
        let res = KMeans::new(KMeansConfig { k: 2, ..Default::default() }).fit(&ds, &mut rng);
        let score = ari(ds.labels.as_ref().unwrap(), &res.assignments);
        assert!(score < 0.3, "k-means unexpectedly separated rings: ARI={score}");
    }
}
