//! Mini-batch k-means (Sculley 2010) with pluggable learning rate —
//! sklearn's `α = b_j/c_j` or Schwartzman (2023)'s `α = √(b_j/b)`.
//!
//! The center update is `c_j ← (1−α)·c_j + α·mean(batch members)`, exactly
//! the kernelized update of Algorithm 1 specialized to the linear kernel —
//! tests exploit that correspondence.

use super::{assign_to_centers, kmeanspp_features, sqdist_to_center};
use crate::data::Dataset;
use crate::kkmeans::learning_rate::{LearningRate, RateState};
use crate::kkmeans::FitResult;
use crate::util::rng::Rng;
use crate::util::timing::{Profiler, Stopwatch};

/// Configuration for [`MiniBatchKMeans`].
#[derive(Clone, Debug)]
pub struct MiniBatchKMeansConfig {
    /// Number of clusters.
    pub k: usize,
    /// Batch size `b` (uniform with repetitions).
    pub batch_size: usize,
    /// Iteration budget.
    pub max_iters: usize,
    /// Early-stopping ε on batch improvement; `None` = fixed iterations.
    pub epsilon: Option<f64>,
    /// Learning-rate schedule for the center updates.
    pub learning_rate: LearningRate,
}

impl Default for MiniBatchKMeansConfig {
    fn default() -> Self {
        MiniBatchKMeansConfig {
            k: 2,
            batch_size: 1024,
            max_iters: 200,
            epsilon: None,
            learning_rate: LearningRate::Beta,
        }
    }
}

/// Mini-batch k-means runner.
pub struct MiniBatchKMeans {
    cfg: MiniBatchKMeansConfig,
}

impl MiniBatchKMeans {
    /// Wrap a configuration.
    pub fn new(cfg: MiniBatchKMeansConfig) -> Self {
        MiniBatchKMeans { cfg }
    }

    /// Run Sculley-style mini-batch k-means on raw features.
    pub fn fit(&self, ds: &Dataset, rng: &mut Rng) -> FitResult {
        let k = self.cfg.k;
        let d = ds.d;
        let b = self.cfg.batch_size.min(ds.n.max(1));
        assert!(k >= 1 && k <= ds.n);
        let mut prof = Profiler::new();

        let sw = Stopwatch::start();
        let mut centers = kmeanspp_features(ds, k, rng);
        let mut rate = RateState::new(self.cfg.learning_rate, k);
        prof.add("init", sw.secs());

        let mut history = Vec::new();
        let mut iterations = 0;
        let mut converged = false;

        for _ in 0..self.cfg.max_iters {
            iterations += 1;
            let sw = Stopwatch::start();
            let batch = rng.sample_with_replacement(ds.n, b);
            // Assign batch + batch objective before update.
            let mut members: Vec<Vec<usize>> = vec![Vec::new(); k];
            let mut f_before = 0.0;
            for &x in &batch {
                let row = ds.row(x);
                let mut best = 0;
                let mut bestv = f64::INFINITY;
                for j in 0..k {
                    let v = sqdist_to_center(row, &centers[j * d..(j + 1) * d]);
                    if v < bestv {
                        best = j;
                        bestv = v;
                    }
                }
                members[best].push(x);
                f_before += bestv;
            }
            f_before /= b as f64;
            history.push(f_before);
            prof.add("assign", sw.secs());

            let sw = Stopwatch::start();
            for j in 0..k {
                let bj = members[j].len();
                let alpha = rate.alpha(j, bj, b);
                if alpha == 0.0 {
                    continue;
                }
                // mean of batch members
                let mut mean = vec![0.0f64; d];
                for &x in &members[j] {
                    for (m, &v) in mean.iter_mut().zip(ds.row(x)) {
                        *m += v as f64;
                    }
                }
                for m in mean.iter_mut() {
                    *m /= bj as f64;
                }
                for (c, m) in centers[j * d..(j + 1) * d].iter_mut().zip(mean.iter()) {
                    *c = (1.0 - alpha) * *c + alpha * m;
                }
            }
            prof.add("update", sw.secs());

            if let Some(eps) = self.cfg.epsilon {
                let sw = Stopwatch::start();
                let mut f_after = 0.0;
                for &x in &batch {
                    let row = ds.row(x);
                    let mut bestv = f64::INFINITY;
                    for j in 0..k {
                        bestv = bestv
                            .min(sqdist_to_center(row, &centers[j * d..(j + 1) * d]));
                    }
                    f_after += bestv;
                }
                f_after /= b as f64;
                prof.add("stopping", sw.secs());
                if f_before - f_after < eps {
                    converged = true;
                    break;
                }
            }
        }

        let sw = Stopwatch::start();
        let (assignments, objective) = assign_to_centers(ds, &centers, k);
        prof.add("finalize", sw.secs());
        FitResult {
            assignments,
            objective,
            history,
            iterations,
            converged,
            decisions: Vec::new(),
            profiler: prof,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};
    use crate::metrics::ari;

    fn fixture() -> Dataset {
        let mut rng = Rng::seeded(31);
        blobs(
            &SyntheticSpec::new(800, 4, 3).with_std(0.4).with_separation(7.0),
            &mut rng,
        )
    }

    #[test]
    fn beta_rate_recovers_blobs() {
        let ds = fixture();
        let mut rng = Rng::seeded(1);
        let cfg = MiniBatchKMeansConfig { k: 3, batch_size: 128, max_iters: 60, ..Default::default() };
        let res = MiniBatchKMeans::new(cfg).fit(&ds, &mut rng);
        assert!(ari(ds.labels.as_ref().unwrap(), &res.assignments) > 0.9);
    }

    #[test]
    fn sklearn_rate_recovers_blobs() {
        let ds = fixture();
        let mut rng = Rng::seeded(2);
        let cfg = MiniBatchKMeansConfig {
            k: 3,
            batch_size: 128,
            max_iters: 60,
            learning_rate: LearningRate::Sklearn,
            ..Default::default()
        };
        let res = MiniBatchKMeans::new(cfg).fit(&ds, &mut rng);
        assert!(ari(ds.labels.as_ref().unwrap(), &res.assignments) > 0.9);
    }

    #[test]
    fn matches_kernel_algorithm1_under_linear_kernel() {
        // Mini-batch k-means ≡ Algorithm 1 with the linear kernel: same
        // seeds ⇒ same batches ⇒ identical assignments and objective.
        use crate::kernels::{Gram, KernelFunction};
        use crate::kkmeans::{MiniBatchConfig, MiniBatchKernelKMeans};
        let mut rng = Rng::seeded(41);
        let ds = blobs(&SyntheticSpec::new(200, 3, 3), &mut rng);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Linear);
        let iters = 15;
        let mut r1 = Rng::seeded(9);
        let mut r2 = Rng::seeded(9);
        let lin = MiniBatchKMeans::new(MiniBatchKMeansConfig {
            k: 3,
            batch_size: 64,
            max_iters: iters,
            ..Default::default()
        })
        .fit(&ds, &mut r1);
        let ker = MiniBatchKernelKMeans::new(MiniBatchConfig {
            k: 3,
            batch_size: 64,
            max_iters: iters,
            init: crate::kkmeans::Init::KMeansPlusPlus,
            ..Default::default()
        })
        .fit(&gram, &mut r2);
        // The feature-space inits differ in representation (explicit point
        // vs index) but use the same D² sampling over the same distances and
        // the same RNG stream, so they pick the same seed points.
        assert_eq!(lin.assignments, ker.assignments);
        assert!((lin.objective - ker.objective).abs() < 1e-6);
    }

    #[test]
    fn early_stopping() {
        // The β rate does not vanish, so the batch improvement has a
        // persistent stochastic floor ~ α²·Var(batch mean) — ε must sit
        // above it (this is exactly Theorem 1's coupling of ε and b).
        let ds = fixture();
        let mut rng = Rng::seeded(3);
        let cfg = MiniBatchKMeansConfig {
            k: 3,
            batch_size: 256,
            max_iters: 500,
            epsilon: Some(0.02),
            ..Default::default()
        };
        let res = MiniBatchKMeans::new(cfg).fit(&ds, &mut rng);
        assert!(res.converged);
        assert!(res.iterations < 500);
    }
}
