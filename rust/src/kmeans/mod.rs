//! Non-kernel k-means baselines: Lloyd's algorithm and Sculley's mini-batch
//! k-means with both learning-rate schedules (β and sklearn).
//!
//! These are the paper's non-kernel comparators (`mb-km` and `β-mb-km` in
//! the figures) and fill the experimental gap the paper notes: evaluating
//! Schwartzman (2023)'s learning rate for plain mini-batch k-means.

mod lloyd;
mod minibatch;

pub use lloyd::{KMeans, KMeansConfig};
pub use minibatch::{MiniBatchKMeans, MiniBatchKMeansConfig};

use crate::data::Dataset;
use crate::util::rng::Rng;

/// k-means++ initialization on raw features: returns k explicit centers
/// (row-major k×d). Every candidate center is a dataset point, so the D²
/// sweep runs point-to-point through [`Dataset::sqdist`] — the cached
/// squared norms plus one inner product per pair, instead of re-deriving
/// per-feature differences against a copied center vector.
pub fn kmeanspp_features(ds: &Dataset, k: usize, rng: &mut Rng) -> Vec<f64> {
    assert!(k >= 1 && k <= ds.n);
    let d = ds.d;
    let mut centers = Vec::with_capacity(k * d);
    let first = rng.below(ds.n);
    centers.extend(ds.row(first).iter().map(|&v| v as f64));
    let mut min_d2: Vec<f64> = (0..ds.n).map(|i| ds.sqdist(i, first)).collect();
    while centers.len() < k * d {
        let next = rng.weighted_choice(&min_d2);
        centers.extend(ds.row(next).iter().map(|&v| v as f64));
        for (i, m) in min_d2.iter_mut().enumerate() {
            let d2 = ds.sqdist(i, next);
            if d2 < *m {
                *m = d2;
            }
        }
    }
    centers
}

#[inline]
pub(crate) fn sqdist_to_center(row: &[f32], center: &[f64]) -> f64 {
    let mut s = 0.0;
    for (x, c) in row.iter().zip(center.iter()) {
        let diff = *x as f64 - c;
        s += diff * diff;
    }
    s
}

/// Assign every point to its nearest explicit center; returns
/// (assignments, mean min squared distance).
pub(crate) fn assign_to_centers(ds: &Dataset, centers: &[f64], k: usize) -> (Vec<usize>, f64) {
    let d = ds.d;
    let assignments = crate::util::parallel::par_map_indexed(ds.n, |i| {
        let row = ds.row(i);
        let mut best = 0usize;
        let mut bestv = f64::INFINITY;
        for j in 0..k {
            let v = sqdist_to_center(row, &centers[j * d..(j + 1) * d]);
            if v < bestv {
                best = j;
                bestv = v;
            }
        }
        best
    });
    let total: f64 = crate::util::parallel::par_fold(
        ds.n,
        0.0,
        |i| {
            let j = assignments[i];
            sqdist_to_center(ds.row(i), &centers[j * d..(j + 1) * d])
        },
        |a, b| a + b,
    );
    (assignments, total / ds.n.max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};

    #[test]
    fn kmeanspp_returns_k_centers_from_data() {
        let mut rng = Rng::seeded(1);
        let ds = blobs(&SyntheticSpec::new(100, 3, 4), &mut rng);
        let c = kmeanspp_features(&ds, 4, &mut rng);
        assert_eq!(c.len(), 4 * 3);
        // Each center equals some dataset row.
        for j in 0..4 {
            let cj = &c[j * 3..(j + 1) * 3];
            let found = (0..ds.n).any(|i| {
                ds.row(i)
                    .iter()
                    .zip(cj.iter())
                    .all(|(a, b)| (*a as f64 - b).abs() < 1e-12)
            });
            assert!(found, "center {j} is not a dataset point");
        }
    }

    #[test]
    fn assign_to_centers_picks_nearest() {
        let ds = Dataset::new("t", vec![0.0, 0.0, 10.0, 0.0, 0.1, 0.0], 3, 2);
        let centers = vec![0.0, 0.0, 10.0, 0.0];
        let (assign, obj) = assign_to_centers(&ds, &centers, 2);
        assert_eq!(assign, vec![0, 1, 0]);
        assert!((obj - (0.0 + 0.0 + 0.01) / 3.0).abs() < 1e-9);
    }
}
