//! Lazy ≡ eager equivalence (the tentpole acceptance property of ISSUE 5).
//!
//! Algorithm 1's eager full-n `px` sweep was replaced by lazy,
//! generation-stamped state (`kkmeans::state::LazyAssignState`): each
//! point's `⟨φ(x), C_j⟩` row carries the generation it was last refreshed
//! at, and a refresh replays only the update-log entries appended since.
//! The replay performs the *same recursion steps in the same order over
//! the same kernel values* as the removed sweep, so a lazy fit must be
//! **bit-identical** to the eager implementation: identical assignment
//! vectors, identical objective bits, identical history bits — across
//! both learning rates, weighted and unweighted, with and without early
//! stopping, on the materialized, streaming (tile-LRU), and on-the-fly
//! providers.
//!
//! The eager reference below is a faithful transcription of the removed
//! sweep (per-element kernel evaluation, member-order accumulation,
//! fused post-update argmin); the property drives both implementations
//! from identically seeded RNGs.

use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::data::Dataset;
use mbkk::kernels::{CachedGram, Gram, KernelFunction, KernelProvider};
use mbkk::kkmeans::backend::argmin_rows;
use mbkk::kkmeans::init::choose_centers;
use mbkk::kkmeans::learning_rate::RateState;
use mbkk::kkmeans::objective::weighted_mean;
use mbkk::kkmeans::{
    EpsilonStopper, Init, LearningRate, MiniBatchConfig, MiniBatchKernelKMeans, ScheduleSpec,
    TerminationMode,
};
use mbkk::testutil::prop::{check_with_seed, from_fn};
use mbkk::util::rng::Rng;

fn dataset(seed: u64, n: usize) -> Dataset {
    let mut rng = Rng::seeded(seed ^ 0xA7);
    blobs(
        &SyntheticSpec::new(n, 4, 3).with_std(0.6).with_separation(5.0),
        &mut rng,
    )
}

/// The removed eager Algorithm 1, transcribed: full-n px table at init,
/// full-n DP sweep + fused argmin every iteration. Returns
/// (assignments, objective, history, iterations, converged).
#[allow(clippy::too_many_arguments)]
fn eager_fit(
    gram: &dyn KernelProvider,
    k: usize,
    b: usize,
    max_iters: usize,
    epsilon: Option<f64>,
    lr: LearningRate,
    init: Init,
    weights: Option<&[f64]>,
    rng: &mut Rng,
) -> (Vec<usize>, f64, Vec<f64>, usize, bool) {
    let n = gram.n();
    let b = b.min(n.max(1));
    let seeds = choose_centers(gram, k, init, rng);
    let mut px = vec![0.0f64; n * k];
    for x in 0..n {
        for (j, &s) in seeds.iter().enumerate() {
            px[x * k + j] = gram.eval(x, s);
        }
    }
    let mut cc: Vec<f64> = seeds.iter().map(|&s| gram.self_k(s)).collect();
    let mut rate = RateState::new(lr, k);
    let mut history = Vec::new();
    let mut assign_all = vec![0usize; n];
    let mut mins_all = vec![0.0f64; n];
    let mut have_assignment = false;
    let mut iterations = 0;
    let mut converged = false;
    // The eager reference drives the same windowed stopping rule as the
    // crate default, so the ε path stays bit-comparable.
    let mut stopper = epsilon.map(|eps| EpsilonStopper::new(eps, TerminationMode::default()));

    for iter in 0..max_iters {
        iterations += 1;
        let batch = rng.sample_with_replacement(n, b);
        let mut batch_dist = vec![0.0f64; b * k];
        for (r, &x) in batch.iter().enumerate() {
            let kxx = gram.self_k(x);
            for j in 0..k {
                batch_dist[r * k + j] = (kxx - 2.0 * px[x * k + j] + cc[j]).max(0.0);
            }
        }
        let (assign, mins) = argmin_rows(&batch_dist, k);
        let f_before = weighted_mean(&batch, &mins, weights);
        history.push(f_before);

        let mut members: Vec<Vec<usize>> = vec![Vec::new(); k];
        for (r, &j) in assign.iter().enumerate() {
            members[j].push(batch[r]);
        }
        let alphas: Vec<f64> = (0..k).map(|j| rate.alpha(j, members[j].len(), b)).collect();
        let mass: Vec<f64> = members
            .iter()
            .map(|m| match weights {
                None => m.len() as f64,
                Some(w) => m.iter().map(|&x| w[x]).sum(),
            })
            .collect();
        let c_dot_cm: Vec<f64> = (0..k)
            .map(|j| {
                if members[j].is_empty() {
                    return 0.0;
                }
                let mut s = 0.0;
                for &y in &members[j] {
                    let wy = weights.map(|w| w[y]).unwrap_or(1.0);
                    s += wy * px[y * k + j];
                }
                s / mass[j]
            })
            .collect();
        let cm_dot_cm: Vec<f64> = (0..k)
            .map(|j| {
                if members[j].is_empty() {
                    return 0.0;
                }
                let pts = &members[j];
                let mut s = 0.0;
                for (a, &y) in pts.iter().enumerate() {
                    let wy = weights.map(|w| w[y]).unwrap_or(1.0);
                    s += wy * wy * gram.self_k(y);
                    for &z in pts.iter().skip(a + 1) {
                        let wz = weights.map(|w| w[z]).unwrap_or(1.0);
                        s += 2.0 * wy * wz * gram.eval(y, z);
                    }
                }
                s / (mass[j] * mass[j])
            })
            .collect();

        for j in 0..k {
            let a = alphas[j];
            if a == 0.0 {
                continue;
            }
            cc[j] = (1.0 - a) * (1.0 - a) * cc[j]
                + 2.0 * a * (1.0 - a) * c_dot_cm[j]
                + a * a * cm_dot_cm[j];
        }
        // The eager full-n sweep with the fused post-update argmin.
        for x in 0..n {
            for j in 0..k {
                let a = alphas[j];
                if a == 0.0 {
                    continue;
                }
                let mut cross = 0.0;
                match weights {
                    None => {
                        for &y in &members[j] {
                            cross += gram.eval(x, y);
                        }
                    }
                    Some(w) => {
                        for &y in &members[j] {
                            cross += w[y] * gram.eval(x, y);
                        }
                    }
                }
                px[x * k + j] = (1.0 - a) * px[x * k + j] + a * cross / mass[j];
            }
            let kxx = gram.self_k(x);
            let mut best = 0usize;
            let mut bestv = f64::INFINITY;
            for j in 0..k {
                let d = (kxx - 2.0 * px[x * k + j] + cc[j]).max(0.0);
                if d < bestv {
                    best = j;
                    bestv = d;
                }
            }
            assign_all[x] = best;
            mins_all[x] = bestv;
        }
        have_assignment = true;

        if let Some(stopper) = stopper.as_mut() {
            let mins_after: Vec<f64> = batch.iter().map(|&x| mins_all[x]).collect();
            let f_after = weighted_mean(&batch, &mins_after, weights);
            if stopper.observe(iter, f_before - f_after) {
                converged = true;
                break;
            }
        }
    }

    if !have_assignment {
        for x in 0..n {
            let kxx = gram.self_k(x);
            let mut best = 0usize;
            let mut bestv = f64::INFINITY;
            for j in 0..k {
                let d = (kxx - 2.0 * px[x * k + j] + cc[j]).max(0.0);
                if d < bestv {
                    best = j;
                    bestv = d;
                }
            }
            assign_all[x] = best;
            mins_all[x] = bestv;
        }
    }
    let points: Vec<usize> = (0..n).collect();
    let objective = weighted_mean(&points, &mins_all, weights);
    (assign_all, objective, history, iterations, converged)
}

/// Run the real (lazy) fit and the eager reference from identically
/// seeded RNGs and demand bit-identity.
#[allow(clippy::too_many_arguments)]
fn assert_lazy_equals_eager(
    gram: &dyn KernelProvider,
    label: &str,
    seed: u64,
    k: usize,
    b: usize,
    max_iters: usize,
    epsilon: Option<f64>,
    lr: LearningRate,
    init: Init,
    weights: Option<&[f64]>,
) -> bool {
    let cfg = MiniBatchConfig {
        k,
        batch_size: b,
        schedule: ScheduleSpec::Fixed,
        max_iters,
        epsilon,
        termination: TerminationMode::default(),
        learning_rate: lr,
        init,
        weights: weights.map(|w| w.to_vec()),
    };
    let mut lazy_rng = Rng::seeded(seed);
    let lazy = MiniBatchKernelKMeans::new(cfg).fit(gram, &mut lazy_rng);
    let mut eager_rng = Rng::seeded(seed);
    let (assign, objective, history, iterations, converged) =
        eager_fit(gram, k, b, max_iters, epsilon, lr, init, weights, &mut eager_rng);
    if lazy.assignments != assign {
        eprintln!("{label}: assignments diverged");
        return false;
    }
    if lazy.objective.to_bits() != objective.to_bits() {
        eprintln!(
            "{label}: objective bits diverged: {} vs {objective}",
            lazy.objective
        );
        return false;
    }
    let history_matches = lazy.history.len() == history.len()
        && lazy.history.iter().zip(history.iter()).all(|(a, b)| a.to_bits() == b.to_bits());
    if !history_matches {
        eprintln!("{label}: history diverged");
        return false;
    }
    if lazy.iterations != iterations || lazy.converged != converged {
        eprintln!("{label}: iteration/convergence bookkeeping diverged");
        return false;
    }
    true
}

#[test]
fn lazy_equals_eager_across_rates_weights_and_providers() {
    // Property: for random (seed, n, b), on every provider flavour, both
    // learning rates, weighted and unweighted, the lazy fit reproduces
    // the eager sweep bit-for-bit.
    let gen = from_fn(|rng: &mut Rng| {
        (rng.next_u64(), 80 + rng.below(100), 12 + rng.below(40))
    });
    check_with_seed(
        "lazy ≡ eager (rates × weights × providers)",
        gen,
        |&(seed, n, b)| {
            let ds = dataset(seed, n);
            let fly = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 8.0 });
            let mat = fly.materialize();
            let cached = CachedGram::new(
                Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 8.0 }),
                256 * 1024,
            );
            let w: Vec<f64> = (0..n).map(|i| 1.0 + (i % 4) as f64).collect();
            let providers: [(&dyn KernelProvider, &str); 3] =
                [(&fly, "on-the-fly"), (&mat, "materialized"), (&cached, "streaming")];
            for (gram, pname) in providers {
                for lr in [LearningRate::Beta, LearningRate::Sklearn] {
                    for weights in [None, Some(w.as_slice())] {
                        let label = format!(
                            "{pname}/{lr:?}/w={} seed={seed} n={n} b={b}",
                            weights.is_some()
                        );
                        if !assert_lazy_equals_eager(
                            gram,
                            &label,
                            seed,
                            3,
                            b,
                            10,
                            None,
                            lr,
                            Init::KMeansPlusPlus,
                            weights,
                        ) {
                            return false;
                        }
                    }
                }
            }
            true
        },
        0xBEEF,
        12,
    );
}

#[test]
fn lazy_equals_eager_with_early_stopping() {
    // The ε path re-scores the batch after the update: the lazy state
    // replays that iteration's log entries; the eager sweep read its
    // maintained post-update mins. Both sides feed the same windowed
    // stopper, so: same bits, same stopping iteration.
    let ds = dataset(5, 160);
    let mat = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 8.0 }).materialize();
    for (seed, eps) in [(3u64, 1e-3), (9, 1e-2), (11, 1e-6)] {
        assert!(
            assert_lazy_equals_eager(
                &mat,
                &format!("eps={eps} seed={seed}"),
                seed,
                3,
                32,
                80,
                Some(eps),
                LearningRate::Beta,
                Init::KMeansPlusPlus,
                None,
            ),
            "early-stopping equivalence failed (eps={eps} seed={seed})"
        );
    }
}

#[test]
fn lazy_equals_eager_at_zero_iterations() {
    // max_iters = 0: the finalize pass must assign from the seed columns
    // exactly as the eager init tables did.
    let ds = dataset(7, 90);
    let fly = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 8.0 });
    assert!(assert_lazy_equals_eager(
        &fly,
        "zero-iters",
        17,
        4,
        16,
        0,
        None,
        LearningRate::Beta,
        Init::Uniform,
        None,
    ));
}
