//! Checkpoint conformance (ISSUE 4): `StreamingKernelKMeans`
//! snapshot → resume → `partial_fit` must match an uninterrupted run
//! **bit-for-bit** on the same RNG stream.
//!
//! The checkpoint artifact captures the reservoir, every window's raw
//! entry structure (including the incrementally-maintained ⟨Ĉ,Ĉ⟩ cache
//! and its drift counter), the learning-rate counters, and the iteration
//! count — so the resumed twin's entire future trajectory, including
//! reservoir compactions and the cc refresh schedule, is the
//! uninterrupted one. Final-state equality is asserted on the serialized
//! bytes themselves, the strongest possible form.

use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::data::Dataset;
use mbkk::kernels::KernelFunction;
use mbkk::kkmeans::{LearningRate, StreamingKernelKMeans};
use mbkk::util::rng::Rng;
use std::path::PathBuf;

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("mbkk_checkpoint_{tag}_{}.mbkk", std::process::id()))
}

/// Pre-generate a deterministic batch stream so the uninterrupted and the
/// interrupted twin consume identical rows.
fn batch_stream(ds: &Dataset, n_batches: usize, batch: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::seeded(99);
    (0..n_batches)
        .map(|_| {
            let idx = rng.sample_with_replacement(ds.n, batch);
            let mut rows = Vec::with_capacity(batch * ds.d);
            for &i in &idx {
                rows.extend_from_slice(ds.row(i));
            }
            rows
        })
        .collect()
}

#[test]
fn snapshot_resume_matches_uninterrupted_run_bit_for_bit() {
    // Both learning rates: Beta is stateless, Sklearn carries per-center
    // counts the checkpoint must restore exactly.
    for (tag, lr) in [("beta", LearningRate::Beta), ("sklearn", LearningRate::Sklearn)] {
        let mut drng = Rng::seeded(8);
        let ds = blobs(
            &SyntheticSpec::new(2000, 6, 3).with_std(0.4).with_separation(7.0),
            &mut drng,
        );
        let kernel = KernelFunction::Gaussian { kappa: 12.0 };
        // 30 batches of 96 against k=3, tau=40, b=96: the reservoir crosses
        // its 4·k·(τ+b) = 1632-row compaction threshold shortly *after* the
        // iteration-15 checkpoint, so the restored windows and reservoir go
        // through a full compaction remap on the resumed side — any
        // restoration drift would surface as diverging row indices.
        let batches = batch_stream(&ds, 30, 96);

        let mut uninterrupted =
            StreamingKernelKMeans::new(kernel, ds.d, 3, 96, 40, lr);
        let mut rng_a = Rng::seeded(3);
        for b in &batches {
            uninterrupted.partial_fit(b, &mut rng_a);
        }

        let mut first_half = StreamingKernelKMeans::new(kernel, ds.d, 3, 96, 40, lr);
        let mut rng_b = Rng::seeded(3);
        for b in &batches[..15] {
            first_half.partial_fit(b, &mut rng_b);
        }
        let path = tmp_path(tag);
        first_half.snapshot(&path).expect("snapshot");
        drop(first_half);
        let mut resumed = StreamingKernelKMeans::resume(&path).expect("resume");
        std::fs::remove_file(&path).ok();
        assert_eq!(resumed.iterations, 15);
        for b in &batches[15..] {
            // partial_fit only draws from the RNG before the first batch
            // (init), so continuing on rng_b keeps the streams identical.
            resumed.partial_fit(b, &mut rng_b);
        }

        assert_eq!(uninterrupted.iterations, resumed.iterations, "{tag}");
        assert_eq!(uninterrupted.stored_rows(), resumed.stored_rows(), "{tag}");
        assert_eq!(
            uninterrupted.snapshot_bytes(),
            resumed.snapshot_bytes(),
            "{tag}: resumed stream diverged from the uninterrupted run"
        );
        // And the served artifacts agree byte-for-byte too.
        assert_eq!(
            uninterrupted.to_model().to_bytes(),
            resumed.to_model().to_bytes(),
            "{tag}"
        );
    }
}

#[test]
fn snapshot_before_first_batch_roundtrips_and_resumes() {
    let mut rng = Rng::seeded(4);
    let ds = blobs(&SyntheticSpec::new(400, 5, 2), &mut rng);
    let kernel = KernelFunction::Gaussian { kappa: 8.0 };
    let batches = batch_stream(&ds, 6, 64);

    // Checkpoint an untouched stream (no windows yet) and feed the whole
    // stream after resume; a twin fed directly must match bit-for-bit.
    let fresh = StreamingKernelKMeans::new(kernel, ds.d, 2, 64, 30, LearningRate::Beta);
    assert_eq!(fresh.iterations, 0);
    let mut resumed =
        StreamingKernelKMeans::resume_bytes(&fresh.snapshot_bytes()).expect("resume");
    let mut twin = StreamingKernelKMeans::new(kernel, ds.d, 2, 64, 30, LearningRate::Beta);
    let mut rng_a = Rng::seeded(5);
    let mut rng_b = Rng::seeded(5);
    for b in &batches {
        resumed.partial_fit(b, &mut rng_a);
        twin.partial_fit(b, &mut rng_b);
    }
    assert_eq!(resumed.snapshot_bytes(), twin.snapshot_bytes());
}

#[test]
fn repeated_checkpointing_is_stable() {
    // snapshot(resume(snapshot(x))) == snapshot(x): the format is a fixed
    // point after one round trip (no re-encoding drift).
    let mut rng = Rng::seeded(12);
    let ds = blobs(&SyntheticSpec::new(500, 4, 3), &mut rng);
    let mut s = StreamingKernelKMeans::new(
        KernelFunction::Laplacian { sigma: 3.0 },
        ds.d,
        3,
        48,
        25,
        LearningRate::Sklearn,
    );
    for b in &batch_stream(&ds, 10, 48) {
        s.partial_fit(b, &mut rng);
    }
    let once = s.snapshot_bytes();
    let resumed = StreamingKernelKMeans::resume_bytes(&once).expect("resume");
    assert_eq!(resumed.snapshot_bytes(), once);
}
