//! Determinism of the `repro-speedup` deliverable (ISSUE 6 satellite 3).
//!
//! The committed reproduction artifact is the *deterministic* table —
//! metrics, iterations-to-terminate, convergence flags — so two runs with
//! the same options must produce **byte-identical** CSV bytes. Timings
//! live in a separate machine-local file and are deliberately excluded.

use mbkk::coordinator::repro::{deterministic_csv, run_repro, ReproOptions, DETERMINISTIC_HEADER};

fn tiny_opts(seed: u64) -> ReproOptions {
    ReproOptions {
        datasets: vec!["blobs".into(), "moons".into()],
        scale: 0.05,
        seed,
        batch_size: 64,
        tau: 50,
        max_iters: 25,
        epsilon: 1e-3,
        growth: 2.0,
    }
}

#[test]
fn same_seed_produces_byte_identical_deterministic_csv() {
    let opts = tiny_opts(7);
    let a = deterministic_csv(&run_repro(&opts));
    let b = deterministic_csv(&run_repro(&opts));
    assert_eq!(a.as_bytes(), b.as_bytes(), "deterministic artifact is not deterministic");
    // Shape: header + 5 rows (1 full-batch + 4 mini-batch cells) per dataset.
    let lines: Vec<&str> = a.trim_end().lines().collect();
    assert_eq!(lines[0], DETERMINISTIC_HEADER);
    assert_eq!(lines.len(), 1 + 5 * opts.datasets.len());
}

#[test]
fn different_seeds_produce_different_tables() {
    // Negative control: the byte-identity above is not vacuous — the table
    // actually depends on the seed (initialization and batch draws move).
    let a = deterministic_csv(&run_repro(&tiny_opts(7)));
    let b = deterministic_csv(&run_repro(&tiny_opts(8)));
    assert_ne!(a, b, "seed does not influence the deterministic table");
}
