//! Property (ISSUE 4): for a converged mini-batch fit on a small blob
//! dataset, `predict` on the training points reproduces the final
//! training assignments — and the materialized and streaming providers
//! agree with each other bit-for-bit at every stage (fit assignments,
//! frozen artifact bytes, served predictions).

use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::kernels::{CachedGram, Gram, KernelFunction, KernelProvider};
use mbkk::kkmeans::{
    KernelKMeansModel, NativeBackend, TruncatedConfig, TruncatedFit,
    TruncatedMiniBatchKernelKMeans,
};
use mbkk::metrics::ari;
use mbkk::serve::PredictEngine;
use mbkk::util::rng::Rng;

fn fit_on(provider: &dyn KernelProvider) -> TruncatedFit {
    let cfg = TruncatedConfig {
        k: 3,
        batch_size: 128,
        tau: 100,
        max_iters: 40,
        ..Default::default()
    };
    let mut rng = Rng::seeded(1);
    TruncatedMiniBatchKernelKMeans::new(cfg).fit_with_backend(
        provider,
        &mut NativeBackend,
        &mut rng,
    )
}

#[test]
fn predict_reproduces_training_assignments_across_providers() {
    let mut rng = Rng::seeded(8);
    // Well-separated blobs (≈17σ between centers): a converged fit's
    // assignment margins dwarf the f32 table quantization, so the frozen
    // model's exact-arithmetic predictions must reproduce the training
    // assignments point for point.
    let ds = blobs(
        &SyntheticSpec::new(600, 6, 3).with_std(0.4).with_separation(7.0),
        &mut rng,
    );
    let kernel = KernelFunction::Gaussian { kappa: 12.0 };

    let materialized = Gram::on_the_fly(&ds, kernel).materialize();
    let mut fit_mat = fit_on(&materialized);
    let streaming = CachedGram::new(Gram::on_the_fly(&ds, kernel), 4 << 20);
    let mut fit_stream = fit_on(&streaming);

    // The §6 bit-identity contract at fit level: both providers drive the
    // exact same trajectory.
    assert_eq!(fit_mat.result.assignments, fit_stream.result.assignments);
    assert_eq!(
        fit_mat.result.objective.to_bits(),
        fit_stream.result.objective.to_bits()
    );

    // Freezing detaches the centers; the artifacts must be bit-identical
    // across providers (support rows, coefficients, norms, and the
    // incrementally-maintained ⟨Ĉ,Ĉ⟩ all agree).
    let model_mat = KernelKMeansModel::freeze(&ds, kernel, &mut fit_mat.centers);
    let model_stream = KernelKMeansModel::freeze(&ds, kernel, &mut fit_stream.centers);
    assert_eq!(
        model_mat.to_bytes(),
        model_stream.to_bytes(),
        "frozen artifacts must not depend on how the training gram was served"
    );

    // The served model reproduces the final training assignments on the
    // training points — scalar path and batched engine alike.
    let scalar_pred = model_mat.predict_all(&ds);
    assert_eq!(
        scalar_pred, fit_mat.result.assignments,
        "predict must reproduce the final training assignments"
    );
    let engine_pred = PredictEngine::new(&model_mat).predict_dataset(&ds);
    assert_eq!(engine_pred, scalar_pred);

    // Sanity: the run actually converged to the planted structure.
    let score = ari(ds.labels.as_ref().unwrap(), &scalar_pred);
    assert!(score > 0.99, "training ARI={score}");
}

#[test]
fn held_out_points_are_served_consistently_after_a_round_trip() {
    // Same generator family ⇒ same blob structure for held-out queries;
    // the persisted artifact must serve them exactly like the in-memory
    // model, through both the scalar and the batched path.
    let mut rng = Rng::seeded(8);
    let train = blobs(
        &SyntheticSpec::new(600, 6, 3).with_std(0.4).with_separation(7.0),
        &mut rng,
    );
    // Same seed ⇒ the generator draws the same cluster centers, so the
    // held-out points come from the same blobs the model was fitted on.
    let mut rng2 = Rng::seeded(8);
    let held_out = blobs(
        &SyntheticSpec::new(240, 6, 3).with_std(0.4).with_separation(7.0),
        &mut rng2,
    );
    let kernel = KernelFunction::Gaussian { kappa: 12.0 };
    let gram = Gram::on_the_fly(&train, kernel);
    let mut fit = fit_on(&gram);
    let model = KernelKMeansModel::freeze(&train, kernel, &mut fit.centers);
    let loaded = KernelKMeansModel::from_bytes(&model.to_bytes()).expect("round trip");
    let scalar = model.predict_all(&held_out);
    let served = PredictEngine::new(&loaded).predict_dataset(&held_out);
    assert_eq!(scalar, served);
    let score = ari(held_out.labels.as_ref().unwrap(), &served);
    assert!(score > 0.95, "held-out ARI={score}");
}
