//! Batch-schedule properties (ISSUE 6 satellite 1).
//!
//! The nested schedule at growth = 1 must be **bit-identical** to the
//! fixed schedule: carry is zero at growth 1, so every iteration makes
//! exactly the same `sample_with_replacement_into` call against the same
//! RNG position. We demand identical assignment vectors, objective bits,
//! history bits, iteration/convergence bookkeeping, *and* identical
//! post-fit RNG positions — across weighted/unweighted runs on the
//! on-the-fly, materialized, and streaming (tile-LRU) providers, for both
//! Algorithm 1 (`MiniBatchKernelKMeans`) and Algorithm 2
//! (`TruncatedMiniBatchKernelKMeans`).

use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::data::Dataset;
use mbkk::kernels::{CachedGram, Gram, KernelFunction, KernelProvider};
use mbkk::kkmeans::{
    FitResult, MiniBatchConfig, MiniBatchKernelKMeans, ScheduleSpec, TruncatedConfig,
    TruncatedMiniBatchKernelKMeans,
};
use mbkk::metrics::ari;
use mbkk::testutil::prop::{check_with_seed, from_fn};
use mbkk::util::rng::Rng;

fn dataset(seed: u64, n: usize) -> Dataset {
    let mut rng = Rng::seeded(seed ^ 0x5C);
    blobs(
        &SyntheticSpec::new(n, 4, 3).with_std(0.5).with_separation(5.0),
        &mut rng,
    )
}

/// Bitwise FitResult comparison (assignments, objective, history,
/// bookkeeping). Timing/profiler fields are excluded by construction.
fn results_bit_identical(a: &FitResult, b: &FitResult, label: &str) -> bool {
    if a.assignments != b.assignments {
        eprintln!("{label}: assignments diverged");
        return false;
    }
    if a.objective.to_bits() != b.objective.to_bits() {
        eprintln!("{label}: objective bits diverged: {} vs {}", a.objective, b.objective);
        return false;
    }
    let history_ok = a.history.len() == b.history.len()
        && a.history.iter().zip(b.history.iter()).all(|(x, y)| x.to_bits() == y.to_bits());
    if !history_ok {
        eprintln!("{label}: history diverged ({} vs {} entries)", a.history.len(), b.history.len());
        return false;
    }
    if a.iterations != b.iterations || a.converged != b.converged {
        eprintln!("{label}: iteration/convergence bookkeeping diverged");
        return false;
    }
    true
}

/// Run Algorithm 1 under `schedule` from a fresh seed; also return the
/// RNG's next draw after the fit, which pins the stream position.
fn mb_fit(
    gram: &dyn KernelProvider,
    schedule: ScheduleSpec,
    seed: u64,
    b: usize,
    iters: usize,
    weights: Option<Vec<f64>>,
) -> (FitResult, u64) {
    let cfg = MiniBatchConfig {
        k: 3,
        batch_size: b,
        schedule,
        max_iters: iters,
        weights,
        ..Default::default()
    };
    let mut rng = Rng::seeded(seed);
    let fit = MiniBatchKernelKMeans::new(cfg).fit(gram, &mut rng);
    (fit, rng.next_u64())
}

/// Same for Algorithm 2.
fn trunc_fit(
    gram: &dyn KernelProvider,
    schedule: ScheduleSpec,
    seed: u64,
    b: usize,
    iters: usize,
    weights: Option<Vec<f64>>,
) -> (FitResult, u64) {
    let cfg = TruncatedConfig {
        k: 3,
        batch_size: b,
        schedule,
        tau: 120,
        max_iters: iters,
        weights,
        ..Default::default()
    };
    let mut rng = Rng::seeded(seed);
    let fit = TruncatedMiniBatchKernelKMeans::new(cfg).fit(gram, &mut rng);
    (fit, rng.next_u64())
}

#[test]
fn nested_growth_one_is_bitwise_identical_to_fixed() {
    // Property: for random (seed, n, b), on every provider flavour,
    // weighted and unweighted, both algorithms: nested(growth=1) ≡ fixed,
    // down to the RNG stream position after the fit.
    let gen = from_fn(|rng: &mut Rng| {
        (rng.next_u64(), 90 + rng.below(90), 16 + rng.below(48))
    });
    check_with_seed(
        "nested(growth=1) ≡ fixed (providers × weights × algorithms)",
        gen,
        |&(seed, n, b)| {
            let ds = dataset(seed, n);
            let fly = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 8.0 });
            let mat = fly.materialize();
            let cached = CachedGram::new(
                Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 8.0 }),
                256 * 1024,
            );
            let w: Vec<f64> = (0..n).map(|i| 1.0 + (i % 3) as f64).collect();
            let nested1 = ScheduleSpec::Nested { growth: 1.0 };
            let providers: [(&dyn KernelProvider, &str); 3] =
                [(&fly, "on-the-fly"), (&mat, "materialized"), (&cached, "streaming")];
            for (gram, pname) in providers {
                for weights in [None, Some(w.clone())] {
                    let wtag = weights.is_some();
                    let label = format!("alg1/{pname}/w={wtag} seed={seed} n={n} b={b}");
                    let (rf, uf) = mb_fit(gram, ScheduleSpec::Fixed, seed, b, 8, weights.clone());
                    let (rn, un) = mb_fit(gram, nested1, seed, b, 8, weights.clone());
                    if !results_bit_identical(&rf, &rn, &label) {
                        return false;
                    }
                    if uf != un {
                        eprintln!("{label}: RNG stream position diverged");
                        return false;
                    }
                    let label = format!("alg2/{pname}/w={wtag} seed={seed} n={n} b={b}");
                    let (rf, uf) = trunc_fit(gram, ScheduleSpec::Fixed, seed, b, 8, weights.clone());
                    let (rn, un) = trunc_fit(gram, nested1, seed, b, 8, weights.clone());
                    if !results_bit_identical(&rf, &rn, &label) {
                        return false;
                    }
                    if uf != un {
                        eprintln!("{label}: RNG stream position diverged");
                        return false;
                    }
                }
            }
            true
        },
        0x5EED5,
        8,
    );
}

#[test]
fn nested_growth_two_grows_and_still_clusters() {
    // Sanity for growth > 1: history length equals the iteration budget
    // (growth must not confuse termination bookkeeping), quality holds,
    // and the fit is deterministic in the seed.
    let ds = dataset(21, 400);
    let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 8.0 });
    let nested = ScheduleSpec::Nested { growth: 2.0 };
    let (fit, _) = mb_fit(&gram, nested, 77, 32, 30, None);
    assert_eq!(fit.history.len(), 30);
    let score = ari(ds.labels.as_ref().unwrap(), &fit.assignments);
    assert!(score > 0.9, "nested growth-2 ARI={score}");
    let (fit2, _) = mb_fit(&gram, nested, 77, 32, 30, None);
    assert!(results_bit_identical(&fit, &fit2, "nested determinism"));
}

#[test]
fn nested_growth_two_differs_from_fixed() {
    // Negative control: the bit-identity above is not vacuous — at
    // growth 2 the schedules genuinely diverge (batch sizes differ, so the
    // RNG streams and histories separate).
    let ds = dataset(33, 300);
    let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 8.0 });
    let (rf, uf) = mb_fit(&gram, ScheduleSpec::Fixed, 5, 32, 12, None);
    let (rn, un) = mb_fit(&gram, ScheduleSpec::Nested { growth: 2.0 }, 5, 32, 12, None);
    let same_history = rf
        .history
        .iter()
        .zip(rn.history.iter())
        .all(|(a, b)| a.to_bits() == b.to_bits());
    assert!(
        !same_history || uf != un,
        "growth=2 produced a run indistinguishable from fixed"
    );
}
