//! Streaming ≡ materialized equivalence (the tentpole acceptance property
//! of ISSUE 2).
//!
//! The streaming provider ([`CachedGram`]) quantizes every kernel value to
//! f32 — the same rounding the materialized table applies on store — and
//! performs its reductions in the materialized fast path's order, so a
//! mini-batch run served by the tile-LRU cache must be **bit-identical**
//! to the same run served by the dense n×n table: identical assignment
//! vectors and identical objective bits, for any seed, batch size, τ,
//! cache budget, and kernel family (Gaussian feature kernel and the knn
//! graph kernel are pinned here).
//!
//! Full-batch Lloyd's is deliberately *not* in the bit-identity roster:
//! its materialized fast path reduces the term3 row sums in a different
//! association order than the eval path (2·Σ vs Σ·2), which is a ulp-level
//! difference by construction — and full-batch over a streamed gram is the
//! O(n²)-per-iteration anti-pattern the streaming path exists to avoid.
//! The coordinator enforces this: `GramStrategy::resolve` routes full-kkm
//! to the materialized table (or fails fast when it cannot fit), so the
//! streamed-full-batch combination is unreachable through `run_one_with`
//! and the CLI.

use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::data::Dataset;
use mbkk::kernels::{graph, CachedGram, Gram, KernelFunction, KernelProvider};
use mbkk::kkmeans::{
    Init, LearningRate, MiniBatchConfig, MiniBatchKernelKMeans, TruncatedConfig,
    TruncatedMiniBatchKernelKMeans,
};
use mbkk::testutil::prop::{check_with_seed, from_fn};
use mbkk::util::rng::Rng;

/// One fit summary: (algorithm label, assignments, objective bits).
type FitSummary = (String, Vec<usize>, u64);

fn dataset(seed: u64, n: usize) -> Dataset {
    let mut rng = Rng::seeded(seed ^ 0xD5);
    blobs(
        &SyntheticSpec::new(n, 4, 3).with_std(0.6).with_separation(5.0),
        &mut rng,
    )
}

/// Run every mini-batch variant against `gram` with a fixed seed.
fn fit_roster(gram: &dyn KernelProvider, seed: u64, b: usize, tau: usize) -> Vec<FitSummary> {
    let mut out = Vec::new();
    for lr in [LearningRate::Beta, LearningRate::Sklearn] {
        let cfg = MiniBatchConfig {
            k: 3,
            batch_size: b,
            max_iters: 12,
            epsilon: None,
            learning_rate: lr,
            init: Init::KMeansPlusPlus,
            weights: None,
            ..Default::default()
        };
        let mut rng = Rng::seeded(seed);
        let fit = MiniBatchKernelKMeans::new(cfg).fit(gram, &mut rng);
        out.push((format!("mb-kkm/{lr:?}"), fit.assignments, fit.objective.to_bits()));
    }
    for tau in [tau, usize::MAX] {
        let cfg = TruncatedConfig {
            k: 3,
            batch_size: b,
            tau,
            max_iters: 12,
            epsilon: Some(1e-9),
            learning_rate: LearningRate::Beta,
            init: Init::KMeansPlusPlus,
            weights: None,
            ..Default::default()
        };
        let mut rng = Rng::seeded(seed ^ 0x7A0);
        let fit = TruncatedMiniBatchKernelKMeans::new(cfg).fit(gram, &mut rng);
        out.push((format!("trunc-kkm/tau={tau}"), fit.assignments, fit.objective.to_bits()));
    }
    out
}

fn assert_identical(mat: &[FitSummary], stream: &[FitSummary]) -> bool {
    assert_eq!(mat.len(), stream.len());
    for ((name_m, assign_m, obj_m), (name_s, assign_s, obj_s)) in
        mat.iter().zip(stream.iter())
    {
        assert_eq!(name_m, name_s);
        if assign_m != assign_s {
            eprintln!("{name_m}: assignments diverged");
            return false;
        }
        if obj_m != obj_s {
            eprintln!(
                "{name_m}: objective bits diverged: {} vs {}",
                f64::from_bits(*obj_m),
                f64::from_bits(*obj_s)
            );
            return false;
        }
    }
    true
}

#[test]
fn gaussian_streaming_equals_materialized() {
    // Property: for random (seed, n, b) the tile-LRU streaming provider
    // and the materialized table produce bit-identical runs.
    let gen = from_fn(|rng: &mut Rng| {
        (rng.next_u64(), 90 + rng.below(120), 16 + rng.below(48))
    });
    check_with_seed(
        "gaussian streaming ≡ materialized",
        gen,
        |&(seed, n, b)| {
            let ds = dataset(seed, n);
            let kernel = KernelFunction::Gaussian { kappa: 9.0 };
            let mat = Gram::on_the_fly(&ds, kernel).materialize();
            let cached = CachedGram::new(Gram::on_the_fly(&ds, kernel), 2 << 20);
            let a = fit_roster(&mat, seed, b, 30);
            let z = fit_roster(&cached, seed, b, 30);
            assert_identical(&a, &z)
        },
        0xE0_15EED,
        8,
    );
}

#[test]
fn knn_streaming_equals_materialized() {
    // Same property through the knn graph kernel: the cache layer wraps
    // the precomputed table and must be fully transparent.
    for seed in [3u64, 11, 27] {
        let ds = dataset(seed, 150);
        let base = graph::knn_kernel(&ds, 8);
        let mat = base.materialize(); // clone of the dense table
        let cached = CachedGram::new(base, 1 << 20);
        let a = fit_roster(&mat, seed, 32, 40);
        let z = fit_roster(&cached, seed, 32, 40);
        assert!(assert_identical(&a, &z), "seed {seed}");
    }
}

#[test]
fn eviction_churn_does_not_change_results() {
    // A pathologically small cache budget (constant generation turnover)
    // must produce the same bits as an ample one: the cache is a pure
    // memoization layer, never a source of truth.
    let ds = dataset(5, 200);
    let kernel = KernelFunction::Gaussian { kappa: 9.0 };
    let ample = CachedGram::new(Gram::on_the_fly(&ds, kernel), 16 << 20);
    let starved = CachedGram::new(Gram::on_the_fly(&ds, kernel), 0);
    let a = fit_roster(&ample, 5, 32, 30);
    let z = fit_roster(&starved, 5, 32, 30);
    assert!(assert_identical(&a, &z));
    let st = starved.cache_stats();
    assert!(st.evictions > 0, "starved cache must have evicted tiles");
    assert!(st.resident_tiles <= st.max_tiles);
}

#[test]
fn streaming_memory_stays_bounded_during_a_fit() {
    // The acceptance-criterion shape check at test scale: a fit through a
    // small cache never exceeds the cache's tile ceiling even though the
    // run touches every row of an (implicit) n×n gram.
    let ds = dataset(9, 600);
    let kernel = KernelFunction::Gaussian { kappa: 9.0 };
    let cached = CachedGram::new(Gram::on_the_fly(&ds, kernel), 256 * 1024);
    let cfg = TruncatedConfig {
        k: 3,
        batch_size: 64,
        tau: 50,
        max_iters: 25,
        epsilon: None,
        learning_rate: LearningRate::Beta,
        init: Init::KMeansPlusPlus,
        weights: None,
        ..Default::default()
    };
    let mut rng = Rng::seeded(1);
    let fit = TruncatedMiniBatchKernelKMeans::new(cfg).fit(&cached, &mut rng);
    assert!(fit.objective.is_finite());
    let st = cached.cache_stats();
    assert!(
        st.resident_tiles <= st.max_tiles,
        "resident {} > ceiling {}",
        st.resident_tiles,
        st.max_tiles
    );
    // The support window recurs across iterations, so the cache must
    // actually be earning its keep (strictly positive hit rate).
    assert!(st.hit_rate() > 0.1, "hit rate {:.3}", st.hit_rate());
}
