//! Sharded-serving conformance suite (ISSUE 10): shard-merge
//! bit-identity, delta replication, and failover — over the public API
//! and real sockets.
//!
//! Contract under test (DESIGN.md §14, ADR-006, docs/API.md):
//!
//! 1. A sharded `ShardSet` answers **bit-identical** assignments to the
//!    single-node scalar path for S ∈ {1, 2, 3, 8}, for odd explicit
//!    bounds, and through the coalescer under concurrent submitters —
//!    the fixed-shard-order merge reproduces the full distance matrix.
//! 2. A kind-`delta` artifact replayed onto a replica resumed from the
//!    base snapshot reproduces the primary's snapshot **byte-equal**;
//!    stale bases are rejected with the replica untouched.
//! 3. Failover: a replica killed mid-batch is retried/failed-over and the
//!    answer is still bit-identical; an unavailable shard answers 503
//!    `shard_unavailable` (strict) or a degraded `"partial": true` answer
//!    (opt-in) — the process never panics and `/healthz` tells the truth
//!    with structured cause codes.

use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::data::Dataset;
use mbkk::kernels::{KernelFunction, NumericsMode};
use mbkk::kkmeans::{CenterWindow, KernelKMeansModel, LearningRate, StreamingKernelKMeans};
use mbkk::serve::coalesce::{CoalesceConfig, Coalescer};
use mbkk::serve::format;
use mbkk::serve::http::{ModelSpec, ServeConfig, Server};
use mbkk::serve::replicate::{apply_delta, capture_base, delta_from, ArtifactWatch};
use mbkk::serve::shard::{ShardPlan, ShardSet, ShardSetConfig, ShardWorkerServer};
use mbkk::util::failpoint;
use mbkk::util::json::Json;
use mbkk::util::rng::Rng;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

// ---- fixtures -------------------------------------------------------------

/// A small servable model with irregular support sizes (the
/// conformance_http idiom).
fn model_for(d: usize, seed: u64) -> (Dataset, KernelKMeansModel) {
    let mut rng = Rng::seeded(seed);
    let ds = blobs(&SyntheticSpec::new(160, d, 3), &mut rng);
    let mut windows: Vec<CenterWindow> =
        (0..3).map(|j| CenterWindow::new(j * 7, 23)).collect();
    for step in 0..12 {
        for (j, w) in windows.iter_mut().enumerate() {
            let pts: Vec<usize> =
                (0..1 + (step + j) % 5).map(|_| rng.below(ds.n)).collect();
            w.apply_update(0.4, &pts, None);
        }
    }
    let model =
        KernelKMeansModel::freeze(&ds, KernelFunction::Gaussian { kappa: 2.0 }, &mut windows);
    (ds, model)
}

fn rows_from(ds: &Dataset, idx: &[usize]) -> Vec<f32> {
    idx.iter().flat_map(|&i| ds.row(i).to_vec()).collect()
}

/// Single-node ground truth: the scalar per-query path.
fn scalar_assignments(model: &KernelKMeansModel, ds: &Dataset, idx: &[usize]) -> Vec<usize> {
    let all = model.predict_all(ds);
    idx.iter().map(|&i| all[i]).collect()
}

fn tiny_backoff() -> ShardSetConfig {
    ShardSetConfig { backoff: Duration::from_micros(100), ..ShardSetConfig::default() }
}

// ---- 1. shard-merge bit-identity ------------------------------------------

#[test]
fn shard_counts_are_bit_identical_to_single_node() {
    let (ds, model) = model_for(6, 101);
    let idx: Vec<usize> = (0..40).map(|i| (i * 3) % ds.n).collect();
    let rows = rows_from(&ds, &idx);
    let want = scalar_assignments(&model, &ds, &idx);
    // S=8 > k=3 exercises empty shards; they must merge as no-ops.
    for s in [1usize, 2, 3, 8] {
        let set = ShardSet::local(
            &model,
            ShardPlan::contiguous(model.k(), s),
            1,
            NumericsMode::Deterministic,
            tiny_backoff(),
        )
        .expect("shard set");
        let got = set.score_batch(&rows).expect("score");
        assert_eq!(got.assignments, want, "S={s} diverged from single-node");
        assert_eq!(got.coverage, 1.0);
        assert!(got.missing.is_empty());
    }
}

#[test]
fn odd_explicit_bounds_are_bit_identical_and_validated() {
    let (ds, model) = model_for(5, 102);
    let idx: Vec<usize> = (0..25).collect();
    let rows = rows_from(&ds, &idx);
    let want = scalar_assignments(&model, &ds, &idx);
    // A maximally lopsided split: one center alone, the rest together.
    let plan = ShardPlan::from_bounds(vec![0, 1, model.k()], model.k()).expect("bounds");
    let set =
        ShardSet::local(&model, plan, 1, NumericsMode::Deterministic, tiny_backoff()).unwrap();
    assert_eq!(set.score_batch(&rows).unwrap().assignments, want);
    // Structural validation: every malformed bounds vector is rejected.
    for bad in [vec![], vec![1, model.k()], vec![0, 2], vec![0, 2, 1, model.k()]] {
        assert!(
            ShardPlan::from_bounds(bad.clone(), model.k()).is_err(),
            "bounds {bad:?} must be rejected"
        );
    }
}

#[test]
fn coalesced_sharded_scoring_matches_under_concurrency() {
    let (ds, model) = model_for(4, 103);
    let set = Arc::new(
        ShardSet::local(
            &model,
            ShardPlan::contiguous(model.k(), 3),
            1,
            NumericsMode::Deterministic,
            tiny_backoff(),
        )
        .unwrap(),
    );
    let coalescer = Arc::new(Coalescer::new(
        Arc::clone(&set),
        CoalesceConfig { max_wait: Duration::from_millis(2), ..CoalesceConfig::default() },
    ));
    let all = model.predict_all(&ds);
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let c = Arc::clone(&coalescer);
            let ds_rows: Vec<(usize, Vec<f32>)> = (0..10)
                .map(|i| {
                    let row = (t * 13 + i * 7) % ds.n;
                    (row, ds.row(row).to_vec())
                })
                .collect();
            let want: Vec<usize> = ds_rows.iter().map(|(r, _)| all[*r]).collect();
            std::thread::spawn(move || {
                for ((_, feats), want_a) in ds_rows.iter().zip(&want) {
                    let scored = c.submit(feats.clone()).expect("coalesced score");
                    assert_eq!(scored.assignments, vec![*want_a]);
                    assert!(scored.coverage.is_none(), "full coverage must not be marked");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("submitter thread");
    }
}

// ---- 2. the shard plan rides the artifact ---------------------------------

#[test]
fn recorded_shard_plan_roundtrips_and_loads_everywhere() {
    let (_ds, model) = model_for(4, 104);
    let plan = ShardPlan::contiguous(model.k(), 2);
    let bytes = format::model_to_bytes_with_plan(&model, Some(plan.bounds()));
    assert_eq!(
        format::model_shard_plan(&bytes).expect("plan parse"),
        Some(plan.bounds().to_vec())
    );
    // A loader that doesn't shard ignores the key entirely.
    let loaded = format::model_from_bytes(&bytes).expect("planned artifact loads");
    assert_eq!(loaded.k(), model.k());
    assert_eq!(loaded.d, model.d);
    // Plain artifacts carry no plan.
    assert_eq!(format::model_shard_plan(&model.to_bytes()).expect("no plan"), None);
}

// ---- 3. delta replication -------------------------------------------------

fn stream_rows(rng: &mut Rng, n: usize, d: usize) -> Vec<f32> {
    (0..n * d).map(|_| rng.normal() as f32).collect()
}

#[test]
fn delta_replay_reproduces_the_primary_byte_for_byte() {
    let mut rng = Rng::seeded(105);
    let mut primary = StreamingKernelKMeans::new(
        KernelFunction::Gaussian { kappa: 3.0 },
        4,
        3,
        16,
        12,
        LearningRate::Sklearn,
    );
    for _ in 0..4 {
        let rows = stream_rows(&mut rng, 16, 4);
        primary.partial_fit(&rows, &mut rng);
    }
    // Generation g: the replica's starting point.
    let base_snapshot = format::stream_to_bytes(&primary);
    let base = capture_base(&primary);
    for _ in 0..3 {
        let rows = stream_rows(&mut rng, 16, 4);
        primary.partial_fit(&rows, &mut rng);
    }
    // The log suffix since g, shipped through the CRC'd v2 container.
    let delta = delta_from(&primary, &base).expect("delta");
    let delta_bytes = format::delta_to_bytes(&delta);
    let decoded = format::delta_from_bytes(&delta_bytes).expect("delta decodes");
    assert_eq!(decoded, delta);

    let mut replica = format::stream_from_bytes(&base_snapshot).expect("resume base");
    apply_delta(&mut replica, &decoded).expect("replay");
    assert_eq!(
        format::stream_to_bytes(&replica),
        format::stream_to_bytes(&primary),
        "replayed replica must snapshot byte-equal to the primary"
    );

    // Catch-up also works through the on-disk artifact path.
    let dir = std::env::temp_dir().join(format!("mbkk-conf-shard-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let path = dir.join("suffix.mbkd");
    format::save_delta(&delta, &path).expect("save delta");
    let loaded = format::load_delta(&path).expect("load delta");
    assert_eq!(loaded, delta);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stale_base_is_rejected_and_the_replica_is_untouched() {
    let mut rng = Rng::seeded(106);
    let mk = || {
        StreamingKernelKMeans::new(
            KernelFunction::Gaussian { kappa: 3.0 },
            4,
            3,
            16,
            12,
            LearningRate::Sklearn,
        )
    };
    let mut primary = mk();
    for _ in 0..3 {
        let rows = stream_rows(&mut rng, 16, 4);
        primary.partial_fit(&rows, &mut rng);
    }
    let base = capture_base(&primary);
    let rows = stream_rows(&mut rng, 16, 4);
    primary.partial_fit(&rows, &mut rng);
    let delta = delta_from(&primary, &base).expect("delta");
    // A replica at a *different* generation must reject the suffix and
    // stay bit-identical to its pre-apply state.
    let mut stranger = mk();
    let rows = stream_rows(&mut rng, 16, 4);
    stranger.partial_fit(&rows, &mut rng);
    let before = format::stream_to_bytes(&stranger);
    assert!(apply_delta(&mut stranger, &delta).is_err());
    assert_eq!(format::stream_to_bytes(&stranger), before);
}

// ---- 4. failover under fault injection ------------------------------------

#[test]
fn killed_replica_mid_batch_is_retried_and_answers_correctly() {
    let _x = failpoint::exclusive_test_lock();
    failpoint::reset();
    let (ds, model) = model_for(4, 107);
    let idx: Vec<usize> = (0..16).collect();
    let rows = rows_from(&ds, &idx);
    let want = scalar_assignments(&model, &ds, &idx);
    let set = ShardSet::local(
        &model,
        ShardPlan::contiguous(model.k(), 2),
        2,
        NumericsMode::Deterministic,
        tiny_backoff(),
    )
    .unwrap();
    // First dispatch dies mid-batch; the retry/failover must answer the
    // *same* assignments — and the process must not panic.
    failpoint::configure("shard.dispatch=1*panic").expect("arm");
    let got = set.score_batch(&rows).expect("failover answers");
    failpoint::clear("shard.dispatch");
    assert_eq!(got.assignments, want);
    assert_eq!(got.coverage, 1.0);
    assert!(failpoint::fired_count("shard.dispatch") >= 1, "the fault must actually fire");
    failpoint::reset();
}

// ---- HTTP-level plumbing --------------------------------------------------

struct TestServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<mbkk::util::error::Result<mbkk::serve::coalesce::StatsSnapshot>>,
}

fn start_server(model: &KernelKMeansModel, tweak: impl FnOnce(&mut ServeConfig)) -> TestServer {
    let mut cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        max_wait: Duration::from_millis(2),
        read_timeout: Duration::from_millis(400),
        shard_backoff: Duration::from_micros(200),
        probe_interval: Duration::from_millis(30),
        ..ServeConfig::default()
    };
    tweak(&mut cfg);
    let server = Server::bind(model, "shard-test.mbkk", &cfg).expect("bind");
    let addr = server.local_addr().expect("local addr");
    let shutdown = server.shutdown_flag();
    let handle = std::thread::spawn(move || server.run());
    TestServer { addr, shutdown, handle }
}

impl TestServer {
    fn stop(self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.handle.join().expect("server thread").expect("server run");
    }
}

struct Resp {
    status: u16,
    body: Json,
}

fn request(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> Resp {
    let s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    let mut writer = s;
    let mut req = format!("{method} {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n");
    if let Some(b) = body {
        req.push_str(&format!("Content-Length: {}\r\n", b.len()));
    }
    req.push_str("\r\n");
    if let Some(b) = body {
        req.push_str(b);
    }
    writer.write_all(req.as_bytes()).expect("send");
    let mut line = String::new();
    reader.read_line(&mut line).expect("status line");
    let status: u16 = line.split_whitespace().nth(1).expect("code").parse().expect("code");
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).expect("header");
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                len = value.trim().parse().expect("length");
            }
        }
    }
    let mut raw = vec![0u8; len];
    reader.read_exact(&mut raw).expect("body");
    let body = Json::parse(std::str::from_utf8(&raw).expect("utf8")).expect("json");
    Resp { status, body }
}

fn points_json(ds: &Dataset, idx: &[usize]) -> String {
    let rows: Vec<String> = idx
        .iter()
        .map(|&i| {
            let cells: Vec<String> = ds.row(i).iter().map(|v| format!("{v}")).collect();
            format!("[{}]", cells.join(","))
        })
        .collect();
    format!("{{\"points\": [{}]}}", rows.join(","))
}

/// An address nothing listens on: bind, read the port, drop the listener.
fn dead_addr() -> String {
    let l = TcpListener::bind("127.0.0.1:0").expect("probe bind");
    let addr = l.local_addr().unwrap().to_string();
    drop(l);
    addr
}

/// Spawn a live `shard-worker` process-equivalent in a thread.
fn spawn_worker(
    model: &KernelKMeansModel,
    plan: &ShardPlan,
    shard: usize,
) -> (String, Arc<AtomicBool>, std::thread::JoinHandle<()>) {
    let server = ShardWorkerServer::bind(model, plan, shard, "127.0.0.1:0", NumericsMode::Deterministic)
        .expect("worker bind");
    let addr = server.local_addr().expect("worker addr").to_string();
    let flag = server.shutdown_flag();
    let handle = std::thread::spawn(move || {
        server.run().expect("worker run");
    });
    (addr, flag, handle)
}

#[test]
fn sharded_http_serving_is_bit_identical_and_fails_over() {
    let (ds, model) = model_for(4, 108);
    let plan = ShardPlan::contiguous(model.k(), 2);
    let (addr0, flag0, h0) = spawn_worker(&model, &plan, 0);
    let (addr1, flag1, h1) = spawn_worker(&model, &plan, 1);
    let srv = start_server(&model, |cfg| {
        cfg.shard_workers = vec![addr0.clone(), addr1.clone()];
        cfg.shard_replicas = 1; // local failover behind each remote
        cfg.shard_deadline = Duration::from_millis(500);
    });
    let idx: Vec<usize> = (0..12).collect();
    let want = scalar_assignments(&model, &ds, &idx);
    let got = |resp: &Resp| -> Vec<usize> {
        resp.body
            .get("assignments")
            .as_arr()
            .expect("assignments")
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect()
    };

    let baseline = request(srv.addr, "POST", "/v1/predict", Some(&points_json(&ds, &idx)));
    assert_eq!(baseline.status, 200);
    assert_eq!(got(&baseline), want, "remote-sharded answer diverged");
    assert!(matches!(baseline.body.get("partial"), Json::Null));

    // Kill worker 0: dispatch falls over to the local replica; the
    // answer stays 200 and bit-identical, the process does not panic.
    flag0.store(true, Ordering::SeqCst);
    h0.join().expect("worker 0");
    for _ in 0..4 {
        let resp = request(srv.addr, "POST", "/v1/predict", Some(&points_json(&ds, &idx)));
        assert_eq!(resp.status, 200, "failover must keep answering");
        assert_eq!(got(&resp), want, "failover answer diverged");
    }
    // /healthz reports per-shard detail truthfully: the dead remote
    // replica has recorded failures; full answers kept status honest.
    let health = request(srv.addr, "GET", "/healthz", None);
    let shards = health.body.get("shards");
    assert!(shards.get("detail").as_arr().is_some(), "healthz must carry shard detail");
    let detail = shards.get("detail").as_arr().unwrap();
    assert_eq!(detail.len(), 2);
    let shard0_replicas = detail[0].get("replicas").as_arr().unwrap();
    assert!(
        shard0_replicas
            .iter()
            .any(|r| r.get("failures").as_f64().unwrap_or(0.0) > 0.0),
        "the dead remote must show failures in /healthz"
    );

    srv.stop();
    flag1.store(true, Ordering::SeqCst);
    h1.join().expect("worker 1");
}

#[test]
fn strict_unavailable_shard_answers_503_and_partial_answers_degraded() {
    let (ds, model) = model_for(4, 109);
    let plan = ShardPlan::contiguous(model.k(), 2);
    let idx: Vec<usize> = (0..8).collect();
    let body = points_json(&ds, &idx);

    // Strict (default): shard 0 has only a dead remote replica → 503
    // shard_unavailable, and /healthz degrades with structured causes.
    let (addr1, flag1, h1) = spawn_worker(&model, &plan, 1);
    let srv = start_server(&model, |cfg| {
        cfg.shard_workers = vec![dead_addr(), addr1.clone()];
        cfg.shard_replicas = 0; // remote-only: no local fallback
        cfg.shard_attempts = 1;
        cfg.shard_deadline = Duration::from_millis(300);
    });
    for _ in 0..3 {
        let resp = request(srv.addr, "POST", "/v1/predict", Some(&body));
        assert_eq!(resp.status, 503, "strict merge must refuse partial answers");
        assert_eq!(resp.body.get("error").get("code").as_str(), Some("shard_unavailable"));
    }
    let health = request(srv.addr, "GET", "/healthz", None);
    assert_eq!(health.status, 200, "degraded still serves health");
    assert_eq!(health.body.get("status").as_str(), Some("degraded"));
    let causes: Vec<String> = health
        .body
        .get("degraded_causes")
        .as_arr()
        .expect("causes array")
        .iter()
        .map(|c| c.as_str().unwrap().to_string())
        .collect();
    assert!(causes.iter().any(|c| c == "shard_unavailable"), "causes: {causes:?}");
    assert!(
        causes.iter().any(|c| c == "replica_ejected"),
        "3 consecutive failures must eject the dead replica: {causes:?}"
    );
    srv.stop();

    // Partial (opt-in): the same outage answers from covered centers,
    // marked "partial" with an honest coverage fraction.
    let srv = start_server(&model, |cfg| {
        cfg.shard_workers = vec![dead_addr(), addr1.clone()];
        cfg.shard_replicas = 0;
        cfg.shard_attempts = 1;
        cfg.shard_deadline = Duration::from_millis(300);
        cfg.partial_results = true;
    });
    let resp = request(srv.addr, "POST", "/v1/predict", Some(&body));
    assert_eq!(resp.status, 200, "partial policy must answer");
    assert_eq!(resp.body.get("partial").as_bool(), Some(true));
    let coverage = resp.body.get("coverage").as_f64().expect("coverage fraction");
    assert!(coverage > 0.0 && coverage < 1.0, "coverage {coverage} must be a true fraction");
    let (lo, hi) = plan.range(0);
    assert_eq!(coverage, (model.k() - (hi - lo)) as f64 / model.k() as f64);
    // Partial answers are argmin over covered centers — never indices
    // from the missing shard.
    for a in resp.body.get("assignments").as_arr().expect("assignments") {
        let a = a.as_usize().unwrap();
        assert!(a >= hi || a < lo, "assignment {a} points into the dead shard");
    }
    let health = request(srv.addr, "GET", "/healthz", None);
    assert_eq!(health.body.get("status").as_str(), Some("degraded"));
    let causes: Vec<String> = health
        .body
        .get("degraded_causes")
        .as_arr()
        .unwrap()
        .iter()
        .map(|c| c.as_str().unwrap().to_string())
        .collect();
    assert!(causes.iter().any(|c| c == "partial_results"), "causes: {causes:?}");
    srv.stop();

    flag1.store(true, Ordering::SeqCst);
    h1.join().expect("worker 1");
}

// ---- 5. registry routing and hot-swap -------------------------------------

#[test]
fn model_routing_and_artifact_hot_swap() {
    let (ds_a, model_a) = model_for(4, 110);
    let (_ds_b, model_b) = model_for(4, 111);
    let dir = std::env::temp_dir().join(format!("mbkk-conf-swap-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let path = dir.join("swap.mbkk");
    format::atomic_write(&path, &model_a.to_bytes()).expect("write a");
    let (watch, bytes) = ArtifactWatch::new(&path).expect("watch");
    let watched = format::model_from_bytes(&bytes).expect("load a");

    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        max_wait: Duration::from_millis(2),
        read_timeout: Duration::from_millis(400),
        ..ServeConfig::default()
    };
    let server = Server::bind_registry(
        vec![
            ModelSpec { name: "primary".to_string(), model: watched, watch: Some(watch) },
            ModelSpec { name: "secondary".to_string(), model: model_b.clone(), watch: None },
        ],
        &cfg,
    )
    .expect("bind registry");
    let addr = server.local_addr().expect("addr");
    let shutdown = server.shutdown_flag();
    let handle = std::thread::spawn(move || server.run());

    let body = points_json(&ds_a, &[0, 1, 2]);
    // Default routing, explicit routing, and the 404 for unknown names.
    assert_eq!(request(addr, "POST", "/v1/predict", Some(&body)).status, 200);
    assert_eq!(
        request(addr, "POST", "/v1/predict?model=secondary", Some(&body)).status,
        200
    );
    let missing = request(addr, "POST", "/v1/predict?model=nope", Some(&body));
    assert_eq!(missing.status, 404);
    assert_eq!(missing.body.get("error").get("code").as_str(), Some("model_not_found"));

    let models = request(addr, "GET", "/v1/models", None);
    let entries = models.body.get("models").as_arr().expect("models");
    assert_eq!(entries.len(), 2);
    let primary = &entries[0];
    let version_before = primary.get("version").as_f64().expect("version");
    assert!(primary.get("requests").as_f64().expect("requests") >= 1.0);
    assert_eq!(primary.get("swaps").as_f64(), Some(0.0));

    // Rewrite the artifact: within the refresh interval the unit is
    // rebuilt and the version/swaps counters move.
    format::atomic_write(&path, &model_b.to_bytes()).expect("write b");
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let swapped = loop {
        let models = request(addr, "GET", "/v1/models", None);
        let primary = &models.body.get("models").as_arr().unwrap()[0];
        if primary.get("swaps").as_f64() == Some(1.0) {
            break primary.clone();
        }
        if std::time::Instant::now() > deadline {
            panic!("hot-swap never happened");
        }
        std::thread::sleep(Duration::from_millis(50));
    };
    assert_ne!(swapped.get("version").as_f64().unwrap(), version_before);
    // The swapped-in model still serves.
    assert_eq!(request(addr, "POST", "/v1/predict", Some(&body)).status, 200);

    shutdown.store(true, Ordering::SeqCst);
    handle.join().expect("server thread").expect("server run");
    std::fs::remove_dir_all(&dir).ok();
}
