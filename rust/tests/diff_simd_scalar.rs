//! Differential harness for the runtime-dispatched SIMD micro-kernels
//! (`util::simd`, DESIGN.md §13): every arm the host can execute is driven
//! against the portable scalar chain over adversarial shapes and values,
//! with the module's accuracy contract asserted exactly —
//!
//! * **dot micro-kernels: bitwise.** f32-widened products are exact in
//!   f64, so fused multiply-adds round identically to the scalar
//!   multiply-then-add chain; the harness asserts `to_bits()` equality,
//!   not a tolerance, across dimensions with odd remainders, unaligned
//!   row offsets, denormals, signed zeros, and huge magnitudes.
//! * **batched exp: ≤ [`EXP_ULP_BUDGET`] ulp** against `f64::exp` on
//!   every arm and every lane position (including the scalar remainder
//!   tail), through the denormal output range and the overflow/underflow
//!   clamps, with NaN/±inf propagated.
//! * **integrated fills**: Fast-mode `Gram` blocks stay within the exp
//!   budget for the exp-family kernels and bitwise for the dot-family
//!   kernels; end-to-end Fast fits land on the Deterministic clustering.
//! * **portable arm**: dispatch latches once per process, so the
//!   `MBKK_NUMERICS_PORTABLE=1` leg re-executes this binary as a child
//!   process and asserts Fast ≡ Deterministic *bitwise* there.

use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::data::Dataset;
use mbkk::kernels::{Gram, KernelFunction, KernelPanel, NumericsMode};
use mbkk::kkmeans::{
    Init, LearningRate, ScheduleSpec, TerminationMode, TruncatedConfig,
    TruncatedMiniBatchKernelKMeans,
};
use mbkk::testutil::prop::{check, from_fn};
use mbkk::util::rng::Rng;
use mbkk::util::simd::{self, Arch, EXP_ULP_BUDGET, MR, NR};

/// Dimensions that straddle every interesting micro-kernel boundary:
/// sub-lane, exact lane widths, odd remainders, and a full panel depth.
const ADVERSARIAL_DIMS: [usize; 8] = [1, 2, 3, 7, 8, 15, 16, 128];

/// One adversarial f32 feature: denormals, signed zeros, huge and tiny
/// magnitudes, and ordinary values, so exactness claims are tested where
/// widening and accumulation are least forgiving.
fn adversarial_f32(rng: &mut Rng) -> f32 {
    match rng.below(10) {
        0 => 0.0,
        1 => -0.0,
        2 => f32::from_bits(1), // smallest subnormal
        3 => -f32::from_bits(rng.below(8) as u32 + 1), // negative subnormals
        4 => f32::MIN_POSITIVE,
        5 => 1.0e30,
        6 => -1.0e30,
        7 => 1.0e-30,
        _ => (rng.f64() * 8.0 - 4.0) as f32,
    }
}

/// Pack `NR` columns dimension-major with zero padding, exactly as the
/// panel engine does before calling the micro-kernel.
fn pack_cols(cols: &[Vec<f32>], d: usize) -> Vec<[f64; NR]> {
    let mut pack = vec![[0.0f64; NR]; d];
    for (c, col) in cols.iter().enumerate() {
        for (slab, &v) in pack.iter_mut().zip(col.iter()) {
            slab[c] = v as f64;
        }
    }
    pack
}

// ---------------------------------------------------------------------------
// Dot micro-kernel: bitwise across arms
// ---------------------------------------------------------------------------

#[test]
fn dot_arms_bitwise_on_adversarial_shapes_and_offsets() {
    // Structure-aware fuzz: rows are views into one shared buffer at
    // random (frequently odd, so unaligned) offsets, with adversarial
    // values; every available arm must reproduce the portable chain to
    // the bit for every row count 1..=MR.
    let gen = from_fn(|rng| {
        let d = ADVERSARIAL_DIMS[rng.below(ADVERSARIAL_DIMS.len())];
        let take = 1 + rng.below(MR);
        let offsets: Vec<usize> = (0..take).map(|_| rng.below(9)).collect();
        let buf_len = offsets.iter().max().unwrap() + take * d;
        let buf: Vec<f32> = (0..buf_len).map(|_| adversarial_f32(rng)).collect();
        let cols: Vec<Vec<f32>> =
            (0..NR).map(|_| (0..d).map(|_| adversarial_f32(rng)).collect()).collect();
        (d, offsets, buf, cols)
    });
    check("SIMD dot arms ≡ portable bitwise", gen, |(d, offsets, buf, cols)| {
        let views: Vec<&[f32]> = offsets
            .iter()
            .enumerate()
            .map(|(r, &off)| &buf[off + r * d..off + (r + 1) * d])
            .collect();
        let pack = pack_cols(cols, *d);
        let want = simd::dot_rows_portable(&views, &pack);
        for arch in simd::test_arches() {
            let got = simd::dot_rows_with_arch(arch, &views, &pack);
            for r in 0..views.len() {
                for c in 0..NR {
                    if got[r][c].to_bits() != want[r][c].to_bits() {
                        eprintln!(
                            "{arch:?} d={d} r={r} c={c}: {:e} vs {:e}",
                            got[r][c], want[r][c]
                        );
                        return false;
                    }
                }
            }
        }
        true
    });
}

#[test]
fn dot_arms_bitwise_with_zero_padded_panel_tail() {
    // The panel engine zero-pads the last column panel; padded lanes must
    // come out exactly 0.0 on every arm (0 · x with finite x), and live
    // lanes must be unaffected by their padded neighbours.
    let mut rng = Rng::seeded(113);
    for arch in simd::test_arches() {
        for d in ADVERSARIAL_DIMS {
            for live in 1..NR {
                let rows: Vec<Vec<f32>> = (0..MR)
                    .map(|_| (0..d).map(|_| adversarial_f32(&mut rng)).collect())
                    .collect();
                let cols: Vec<Vec<f32>> = (0..live)
                    .map(|_| (0..d).map(|_| adversarial_f32(&mut rng)).collect())
                    .collect();
                let pack = pack_cols(&cols, d);
                let views: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
                let want = simd::dot_rows_portable(&views, &pack);
                let got = simd::dot_rows_with_arch(arch, &views, &pack);
                for r in 0..MR {
                    for c in 0..NR {
                        assert_eq!(
                            got[r][c].to_bits(),
                            want[r][c].to_bits(),
                            "{arch:?} d={d} live={live} r={r} c={c}"
                        );
                        if c >= live {
                            assert_eq!(got[r][c], 0.0, "padded lane not exactly zero");
                        }
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Batched exp: ulp budget on every arm, every lane position
// ---------------------------------------------------------------------------

/// Assert one arm's batched exp against `f64::exp` within the budget.
fn assert_exp_within_budget(arch: Arch, xs: &[f64]) {
    let mut got = xs.to_vec();
    simd::exp_slice_with_arch(arch, &mut got);
    for (i, (&g, &x)) in got.iter().zip(xs.iter()).enumerate() {
        let want = x.exp();
        match simd::ulp_distance(g, want) {
            Some(d) => assert!(
                d <= EXP_ULP_BUDGET,
                "{arch:?} exp({x:e}) at lane {i}: {g:e} vs {want:e} ({d} ulp)"
            ),
            None => panic!("{arch:?} exp({x:e}) at lane {i}: {g:e} vs {want:e} incomparable"),
        }
    }
}

#[test]
fn exp_arms_within_budget_across_full_range() {
    // Dense sweep across every output regime: overflow clamp, normals,
    // the deep-negative range the Gaussian kernel actually produces,
    // gradual underflow through the subnormals, and the hard-zero clamp.
    let mut xs = Vec::new();
    let mut x = -760.0;
    while x <= 715.0 {
        xs.push(x);
        x += 0.773; // odd step: never lands exactly on the clamps
    }
    xs.extend_from_slice(&[
        0.0,
        -0.0,
        1.0,
        -1.0,
        1e-300,
        -1e-300,
        f64::MIN_POSITIVE / 4.0, // subnormal argument
        709.782712893384,        // EXP_HI exactly
        -746.0,                  // EXP_LO exactly
        -744.8,                  // deepest subnormal outputs
        -745.13,
        709.7827,
        f64::INFINITY,
        f64::NEG_INFINITY,
    ]);
    for arch in simd::test_arches() {
        assert_exp_within_budget(arch, &xs);
    }
}

#[test]
fn exp_arms_handle_nan_and_lane_mixtures() {
    // NaN lanes must stay NaN without contaminating neighbours, even when
    // packed next to clamped and subnormal-producing lanes.
    let xs = [
        f64::NAN,
        -1000.0,
        f64::NAN,
        1000.0,
        -744.5,
        0.5,
        f64::NAN,
        -0.25,
        3.75,
    ];
    for arch in simd::test_arches() {
        let mut got = xs.to_vec();
        simd::exp_slice_with_arch(arch, &mut got);
        for (i, (&g, &x)) in got.iter().zip(xs.iter()).enumerate() {
            if x.is_nan() {
                assert!(g.is_nan(), "{arch:?} lane {i}: NaN in, {g} out");
            } else {
                let d = simd::ulp_distance(g, x.exp()).unwrap();
                assert!(d <= EXP_ULP_BUDGET, "{arch:?} lane {i} off by {d} ulp");
            }
        }
    }
}

#[test]
fn exp_arms_are_lane_position_independent() {
    // A value's result may not depend on where it lands: full lane,
    // remainder tail, or unaligned slice start. Fuzz values through every
    // (length, offset) layout and pin each result to the scalar twin.
    let gen = from_fn(|rng| {
        let len = 1 + rng.below(33);
        let off = rng.below(5);
        let vals: Vec<f64> = (0..off + len)
            .map(|_| match rng.below(8) {
                0 => -746.2 + rng.f64(), // around the zero clamp
                1 => -744.0 - rng.f64(), // subnormal outputs
                2 => 709.5 + rng.f64(),  // around the inf clamp
                3 => rng.f64() * 1e-7,   // near zero
                _ => -rng.f64() * 60.0,  // the Gaussian argument range
            })
            .collect();
        (off, vals)
    });
    check("exp lane-position independence", gen, |(off, vals)| {
        for arch in simd::test_arches() {
            let mut got = vals.clone();
            simd::exp_slice_with_arch(arch, &mut got[*off..]);
            for (i, (&g, &x)) in got[*off..].iter().zip(vals[*off..].iter()).enumerate() {
                let twin = if arch == Arch::Portable { x.exp() } else { simd::exp_fast_scalar(x) };
                if g.to_bits() != twin.to_bits() {
                    eprintln!("{arch:?} off={off} i={i}: {g:e} vs twin {twin:e}");
                    return false;
                }
                match simd::ulp_distance(g, x.exp()) {
                    Some(d) if d <= EXP_ULP_BUDGET => {}
                    _ => {
                        eprintln!("{arch:?} off={off} i={i}: {g:e} vs {:e} over budget", x.exp());
                        return false;
                    }
                }
            }
        }
        true
    });
}

// ---------------------------------------------------------------------------
// Integrated fills: panel and gram under Fast mode
// ---------------------------------------------------------------------------

/// An adversarial dataset: blob structure with a sprinkle of extreme
/// feature values so the fills see denormals and huge magnitudes too.
fn adversarial_dataset(rng: &mut Rng, n: usize, d: usize) -> Dataset {
    let mut ds = blobs(&SyntheticSpec::new(n, d, 3), rng);
    for v in ds.features.iter_mut() {
        if rng.below(50) == 0 {
            *v = adversarial_f32(rng);
        }
    }
    ds.invalidate_caches();
    ds
}

#[test]
fn gram_fast_blocks_hold_the_per_kernel_contract() {
    // Exp-family kernels: every Fast block value within the exp ulp
    // budget of the Deterministic value. Dot-family kernels: bitwise.
    let gen = from_fn(|rng| {
        let d = ADVERSARIAL_DIMS[rng.below(ADVERSARIAL_DIMS.len())];
        let n = 10 + rng.below(40);
        let ds = adversarial_dataset(rng, n, d);
        let func = match rng.below(4) {
            0 => KernelFunction::Gaussian { kappa: 0.5 + rng.f64() * 8.0 },
            1 => KernelFunction::Laplacian { sigma: 0.5 + rng.f64() * 4.0 },
            2 => KernelFunction::Polynomial {
                gamma: 0.1 + rng.f64(),
                coef0: rng.f64(),
                degree: 1 + rng.below(3) as u32,
            },
            _ => KernelFunction::Linear,
        };
        let rows: Vec<usize> = (0..1 + rng.below(17)).map(|_| rng.below(n)).collect();
        let cols: Vec<usize> = (0..1 + rng.below(23)).map(|_| rng.below(n)).collect();
        let tile = 1 + rng.below(cols.len() + 4);
        (ds, func, rows, cols, tile)
    });
    check("Fast gram blocks vs Deterministic", gen, |(ds, func, rows, cols, tile)| {
        let det = Gram::on_the_fly(ds, *func);
        let fast = Gram::on_the_fly_with(ds, *func, NumericsMode::Fast);
        let mut dvals = vec![f64::NAN; rows.len() * cols.len()];
        let mut fvals = vec![f64::NAN; rows.len() * cols.len()];
        det.block_into_tiled(rows, cols, *tile, &mut dvals);
        fast.block_into_tiled(rows, cols, *tile, &mut fvals);
        let exp_family =
            matches!(func, KernelFunction::Gaussian { .. } | KernelFunction::Laplacian { .. });
        for (i, (&dv, &fv)) in dvals.iter().zip(fvals.iter()).enumerate() {
            let ok = if exp_family {
                simd::ulp_distance(dv, fv).is_some_and(|u| u <= EXP_ULP_BUDGET)
            } else {
                dv.to_bits() == fv.to_bits()
            };
            if !ok {
                eprintln!("{func:?} entry {i}: det={dv:e} fast={fv:e}");
                return false;
            }
        }
        // eval() is the deterministic scalar reference on both providers,
        // regardless of mode.
        let (i, j) = (rows[0], cols[0]);
        det.eval(i, j).to_bits() == fast.eval(i, j).to_bits()
    });
}

#[test]
fn panel_single_row_and_block_paths_agree_on_mode_contract() {
    // fill_f64 routes rows.len()==1 through a different fast path than
    // the micro-kernel block path; both must honour the mode contract.
    let mut rng = Rng::seeded(311);
    for d in [3usize, 16] {
        let ds = adversarial_dataset(&mut rng, 30, d);
        let func = KernelFunction::Gaussian { kappa: 3.0 };
        let det = KernelPanel::new(&ds, func);
        let fast = KernelPanel::new_with(&ds, func, NumericsMode::Fast);
        let cols: Vec<usize> = (0..11).map(|_| rng.below(ds.n)).collect();
        for rows in [vec![4usize], vec![1usize, 9, 17, 22, 5, 28]] {
            let mut dvals = vec![f64::NAN; rows.len() * cols.len()];
            let mut fvals = vec![f64::NAN; rows.len() * cols.len()];
            det.fill_f64(&rows, &cols, &mut dvals);
            fast.fill_f64(&rows, &cols, &mut fvals);
            for (i, (&dv, &fv)) in dvals.iter().zip(fvals.iter()).enumerate() {
                let u = simd::ulp_distance(dv, fv)
                    .unwrap_or_else(|| panic!("d={d} entry {i}: {dv:e} vs {fv:e}"));
                assert!(u <= EXP_ULP_BUDGET, "d={d} entry {i}: {u} ulp");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// End-to-end tolerance: Fast fits land on the Deterministic clustering
// ---------------------------------------------------------------------------

fn trunc_fit(gram: &Gram<'_>, k: usize, seed: u64) -> mbkk::kkmeans::FitResult {
    let mut rng = Rng::seeded(seed);
    TruncatedMiniBatchKernelKMeans::new(TruncatedConfig {
        k,
        batch_size: 64,
        schedule: ScheduleSpec::Fixed,
        tau: 60,
        max_iters: 40,
        epsilon: None,
        termination: TerminationMode::default(),
        learning_rate: LearningRate::Beta,
        init: Init::KMeansPlusPlus,
        weights: None,
    })
    .fit(gram, &mut rng)
}

#[test]
fn fast_fit_matches_deterministic_fit_within_tolerance() {
    // Unquantized on-the-fly grams (no f32 table to mask differences):
    // the ≤4-ulp exp perturbation may flip ties but must not change the
    // clustering structure on well-separated data.
    let mut rng = Rng::seeded(65);
    let ds = blobs(&SyntheticSpec::new(600, 8, 5), &mut rng);
    let func = KernelFunction::Gaussian { kappa: 8.0 };
    let det_gram = Gram::on_the_fly(&ds, func);
    let fast_gram = Gram::on_the_fly_with(&ds, func, NumericsMode::Fast);
    let det = trunc_fit(&det_gram, 5, 12);
    let fast = trunc_fit(&fast_gram, 5, 12);
    let agreement = mbkk::metrics::ari(&det.assignments, &fast.assignments);
    assert!(agreement > 0.8, "fast fit diverged from det fit: ARI={agreement}");
    let rel = (det.objective - fast.objective).abs() / det.objective.abs().max(1e-12);
    assert!(rel < 5e-2, "objectives diverged: det={} fast={}", det.objective, fast.objective);
    if simd::detected_arch() == Arch::Portable {
        // Fast degrades to the scalar chain without SIMD hardware, so the
        // fits must then be bit-identical, not merely close.
        assert_eq!(det.objective.to_bits(), fast.objective.to_bits());
        assert_eq!(det.assignments, fast.assignments);
    }
}

// ---------------------------------------------------------------------------
// Portable-arm leg: dispatch latches per process, so re-exec with the
// override and assert Fast ≡ Deterministic bitwise there.
// ---------------------------------------------------------------------------

/// Child half: only runs when re-exec'd by the parent below with
/// `MBKK_SIMD_CHILD` set (dispatch latched to the portable arm via
/// `MBKK_NUMERICS_PORTABLE` before the first kernel call).
#[test]
fn child_portable_fast_is_bit_identical() {
    if std::env::var("MBKK_SIMD_CHILD").is_err() {
        return;
    }
    assert_eq!(simd::detected_arch(), Arch::Portable, "override must pin dispatch");
    let mut rng = Rng::seeded(201);
    let ds = adversarial_dataset(&mut rng, 48, 7);
    for func in [
        KernelFunction::Gaussian { kappa: 4.0 },
        KernelFunction::Laplacian { sigma: 2.0 },
        KernelFunction::Linear,
    ] {
        let det = Gram::on_the_fly(&ds, func);
        let fast = Gram::on_the_fly_with(&ds, func, NumericsMode::Fast);
        let rows: Vec<usize> = (0..ds.n).collect();
        let mut dvals = vec![f64::NAN; ds.n * ds.n];
        let mut fvals = vec![f64::NAN; ds.n * ds.n];
        det.block_into_tiled(&rows, &rows, 13, &mut dvals);
        fast.block_into_tiled(&rows, &rows, 13, &mut fvals);
        for (i, (&dv, &fv)) in dvals.iter().zip(fvals.iter()).enumerate() {
            assert_eq!(dv.to_bits(), fv.to_bits(), "{func:?} entry {i}: {dv:e} vs {fv:e}");
        }
    }
    println!("MBKK_SIMD_RESULT portable-bitwise ok");
}

#[test]
fn portable_override_makes_fast_bit_identical_in_child_process() {
    let exe = std::env::current_exe().expect("current_exe");
    let out = std::process::Command::new(&exe)
        .args(["child_portable_fast_is_bit_identical", "--exact", "--nocapture"])
        .env("MBKK_SIMD_CHILD", "1")
        .env("MBKK_NUMERICS_PORTABLE", "1")
        .output()
        .expect("spawn child test");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "portable child failed:\n{stdout}\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(
        stdout.contains("MBKK_SIMD_RESULT portable-bitwise ok"),
        "child never reached its assertion:\n{stdout}"
    );
}
