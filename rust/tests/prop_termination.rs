//! ε-termination properties (ISSUE 6 satellite 2).
//!
//! The windowed confidence stopper must be a *deterministic function of
//! the seed*: the stop iteration may not depend on the kernel provider or
//! on the worker-pool size. `num_threads()` latches `MBKK_THREADS` once
//! per process, so the thread-count property re-executes this test binary
//! as a subprocess per thread count (`MBKK_TERM_CHILD` gate) and compares
//! the printed stop iteration + objective bits.
//!
//! Also pinned here: for ε > 0 the fit terminates within the ceiling on a
//! well-separated dataset; the rule never fires on iteration 0 even with
//! ε = ∞; and the recorded decision sequence replays exactly through a
//! fresh [`EpsilonStopper`].

use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::data::Dataset;
use mbkk::kernels::{CachedGram, Gram, KernelFunction, KernelProvider};
use mbkk::kkmeans::{
    EpsilonStopper, FitResult, MiniBatchConfig, MiniBatchKernelKMeans, TerminationMode,
};
use mbkk::util::rng::Rng;

fn dataset(seed: u64, n: usize) -> Dataset {
    let mut rng = Rng::seeded(seed ^ 0x7E);
    blobs(
        &SyntheticSpec::new(n, 4, 3).with_std(0.5).with_separation(5.0),
        &mut rng,
    )
}

fn eps_fit(gram: &dyn KernelProvider, seed: u64, epsilon: f64, max_iters: usize) -> FitResult {
    let cfg = MiniBatchConfig {
        k: 3,
        batch_size: 64,
        max_iters,
        epsilon: Some(epsilon),
        ..Default::default()
    };
    let mut rng = Rng::seeded(seed);
    MiniBatchKernelKMeans::new(cfg).fit(gram, &mut rng)
}

#[test]
fn stop_iteration_is_invariant_to_provider() {
    // Same seed ⇒ same stop iteration and identical decision sequences on
    // the on-the-fly, materialized, and streaming providers.
    let ds = dataset(3, 300);
    let fly = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 8.0 });
    let mat = fly.materialize();
    let cached = CachedGram::new(
        Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 8.0 }),
        256 * 1024,
    );
    for seed in [1u64, 7, 19] {
        let a = eps_fit(&fly, seed, 1e-2, 400);
        let b = eps_fit(&mat, seed, 1e-2, 400);
        let c = eps_fit(&cached, seed, 1e-2, 400);
        assert_eq!(a.iterations, b.iterations, "seed {seed}: fly vs materialized");
        assert_eq!(a.iterations, c.iterations, "seed {seed}: fly vs streaming");
        assert_eq!(a.converged, b.converged);
        assert_eq!(a.converged, c.converged);
        assert_eq!(a.decisions, b.decisions, "seed {seed}: decision sequences diverged");
        assert_eq!(a.decisions, c.decisions, "seed {seed}: decision sequences diverged");
    }
}

#[test]
fn terminates_within_ceiling_for_positive_epsilon() {
    // On a well-separated dataset the improvement stream dries up, so the
    // windowed rule must fire well before a generous ceiling.
    let ds = dataset(11, 300);
    let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 8.0 });
    let fit = eps_fit(&gram, 5, 1e-2, 400);
    assert!(fit.converged, "ε rule never fired in 400 iterations");
    assert!(fit.iterations < 400);
    // Decision bookkeeping: one decision per executed iteration, only the
    // last one stops.
    assert_eq!(fit.decisions.len(), fit.iterations);
    assert!(fit.decisions.last().unwrap().stop);
    assert!(fit.decisions[..fit.iterations - 1].iter().all(|d| !d.stop));
}

#[test]
fn never_fires_on_iteration_zero_even_with_infinite_epsilon() {
    // ε = ∞ makes the threshold trivially satisfiable; the rule still may
    // not stop before it has a second sample, so the earliest stop is
    // iteration 1 (two iterations executed).
    let ds = dataset(13, 200);
    let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 8.0 });
    let fit = eps_fit(&gram, 9, f64::INFINITY, 50);
    assert!(!fit.decisions[0].stop, "stopped on iteration 0");
    assert!(fit.converged);
    assert_eq!(fit.iterations, 2);
}

#[test]
fn decision_sequence_replays_through_a_fresh_stopper() {
    // The recorded (iteration, improvement) stream fed into a fresh
    // stopper with the same mode must reproduce every decision bitwise —
    // the RunOutcome decision log is a complete replay transcript.
    let ds = dataset(17, 250);
    let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 8.0 });
    let fit = eps_fit(&gram, 23, 1e-2, 400);
    assert!(!fit.decisions.is_empty());
    let mut replay = EpsilonStopper::new(1e-2, TerminationMode::default());
    for d in &fit.decisions {
        replay.observe(d.iteration, d.improvement);
    }
    assert_eq!(replay.decisions(), fit.decisions.as_slice());
}

/// Child half of the thread-invariance property: only runs when re-exec'd
/// by `stop_iteration_is_invariant_to_thread_count` with the gate set
/// (`MBKK_THREADS` is latched once per process, so each thread count needs
/// its own process).
#[test]
fn child_fit_for_thread_invariance() {
    if std::env::var("MBKK_TERM_CHILD").is_err() {
        return;
    }
    let ds = dataset(29, 300);
    let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 8.0 });
    let fit = eps_fit(&gram, 31, 1e-2, 400);
    println!(
        "MBKK_TERM_RESULT iters={} converged={} obj={:016x} threads={}",
        fit.iterations,
        fit.converged,
        fit.objective.to_bits(),
        mbkk::util::parallel::num_threads(),
    );
}

#[test]
fn stop_iteration_is_invariant_to_thread_count() {
    let exe = std::env::current_exe().expect("current_exe");
    let mut results = Vec::new();
    for threads in ["1", "2", "4"] {
        let out = std::process::Command::new(&exe)
            .args(["child_fit_for_thread_invariance", "--exact", "--nocapture"])
            .env("MBKK_TERM_CHILD", "1")
            .env("MBKK_THREADS", threads)
            .output()
            .expect("spawn child test");
        assert!(out.status.success(), "child (threads={threads}) failed");
        let stdout = String::from_utf8_lossy(&out.stdout);
        let line = stdout
            .lines()
            .find(|l| l.starts_with("MBKK_TERM_RESULT"))
            .unwrap_or_else(|| panic!("no result line (threads={threads}):\n{stdout}"))
            .to_string();
        // Strip the reported thread count before comparing: everything
        // else (stop iteration, convergence flag, objective bits) must be
        // identical across pool sizes.
        let (head, tail) = line.rsplit_once(" threads=").expect("threads field");
        assert_eq!(tail, threads, "MBKK_THREADS not honored: {line}");
        results.push(head.to_string());
    }
    assert_eq!(results[0], results[1], "1 vs 2 threads diverged");
    assert_eq!(results[0], results[2], "1 vs 4 threads diverged");
}
