//! Cross-module integration tests: CSV → kernel → clustering → metrics,
//! registry → figure rows, coreset composition, and backend agreement at
//! the fit level.

use mbkk::coordinator::experiment::{run_one, AlgoSpec, KernelSpec, RunSpec};
use mbkk::coordinator::figures;
use mbkk::data::{csvio, registry};
use mbkk::kernels::{Gram, KernelFunction};
use mbkk::kkmeans::{LearningRate, TruncatedConfig, TruncatedMiniBatchKernelKMeans};
use mbkk::metrics::ari;
use mbkk::util::rng::Rng;

#[test]
fn csv_roundtrip_cluster_pipeline() {
    // Generate → save CSV → load CSV → cluster → evaluate: the full user
    // path of `mbkk run --csv`.
    let mut rng = Rng::seeded(11);
    let ds = mbkk::data::synthetic::blobs(
        &mbkk::data::synthetic::SyntheticSpec::new(400, 5, 3)
            .with_std(0.3)
            .with_separation(7.0),
        &mut rng,
    );
    let dir = std::env::temp_dir().join("mbkk_integration");
    let path = dir.join("blobs.csv");
    csvio::save_csv(&ds, &path).unwrap();
    let loaded = csvio::load_csv(&path).unwrap();
    assert_eq!(loaded.n, ds.n);

    let gram = Gram::on_the_fly(&loaded, KernelFunction::Gaussian { kappa: 10.0 });
    let cfg = TruncatedConfig { k: 3, batch_size: 128, tau: 100, max_iters: 50, ..Default::default() };
    let res = TruncatedMiniBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
    let score = ari(loaded.labels.as_ref().unwrap(), &res.assignments);
    assert!(score > 0.9, "pipeline ARI={score}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn registry_datasets_cluster_above_chance() {
    // Every proxy dataset must be learnable: the truncated algorithm beats
    // chance by a wide margin at small scale.
    for &name in registry::PAPER_PROXIES {
        let spec = RunSpec {
            dataset: name.into(),
            scale: 0.04,
            kernel: KernelSpec::Gaussian { multiplier: 1.0 },
            algo: AlgoSpec::TruncKkm(LearningRate::Beta),
            k: registry::default_k(name),
            batch_size: 128,
            schedule: mbkk::kkmeans::ScheduleSpec::Fixed,
            tau: 100,
            max_iters: 60,
            epsilon: None,
            seed: 5,
            numerics: mbkk::kernels::NumericsMode::Deterministic,
        };
        let out = run_one(&spec);
        assert!(out.ari > 0.15, "{name}: ARI={} too close to chance", out.ari);
        assert!(out.nmi > 0.2, "{name}: NMI={}", out.nmi);
    }
}

#[test]
fn gamma_table_matches_paper_shape() {
    // Paper Table 1's qualitative shape: γ(gaussian)=1 exactly;
    // γ(knn) ≪ γ(heat) < 1.
    let md = figures::run_gamma_table(0.03, 9, None).unwrap();
    for line in md.lines().skip(2) {
        let cols: Vec<&str> = line.split('|').map(str::trim).collect();
        let kernel = cols[2];
        let gamma: f64 = cols[3].parse().unwrap();
        match kernel {
            "gaussian" => assert!((gamma - 1.0).abs() < 1e-6, "{line}"),
            "knn" => assert!(gamma < 0.25, "{line}"),
            "heat" => assert!(gamma < 1.0, "{line}"),
            other => panic!("unexpected kernel {other}"),
        }
    }
}

#[test]
fn figure1_rows_support_paper_ordering() {
    // Tiny figure-1 run: kernel mini-batch quality ≈ full batch (within
    // noise), and every expected algo row is present for all four proxies.
    let opts = figures::FigureOptions {
        scale: 0.03,
        repeats: 2,
        max_iters: 40,
        quick: true,
        seed: 3,
    };
    let rows = figures::run_figure(1, &opts, None).unwrap();
    let datasets: std::collections::BTreeSet<&str> =
        rows.iter().map(|r| r.dataset.as_str()).collect();
    assert_eq!(datasets.len(), 4);
    for &dataset in registry::PAPER_PROXIES {
        let full = rows
            .iter()
            .find(|r| r.dataset == dataset && r.algo == "full-kkm")
            .unwrap();
        let trunc = rows
            .iter()
            .find(|r| r.dataset == dataset && r.algo == "btrunc-kkm")
            .unwrap();
        assert!(
            trunc.ari.mean > full.ari.mean - 0.25,
            "{dataset}: truncated ARI {} collapsed vs full {}",
            trunc.ari.mean,
            full.ari.mean
        );
    }
}

#[test]
fn coreset_then_minibatch_composition() {
    // §2 composability: coreset → weighted truncated mini-batch on a
    // registry dataset keeps quality while shrinking n by 5x.
    let ds = registry::load("synth_pendigits", 0.08, 13);
    let mut rng = Rng::seeded(13);
    let cs = mbkk::data::coreset::uniform_coreset(&ds, ds.n / 5, &mut rng);
    let gram = Gram::on_the_fly(&cs, KernelFunction::Gaussian { kappa: cs.d as f64 });
    let cfg = TruncatedConfig {
        k: 10,
        batch_size: 128,
        tau: 100,
        max_iters: 60,
        weights: cs.weights.clone(),
        ..Default::default()
    };
    let res = TruncatedMiniBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
    let score = ari(cs.labels.as_ref().unwrap(), &res.assignments);
    assert!(score > 0.3, "coreset composition ARI={score}");
}

#[test]
fn xla_and_native_full_fits_agree_statistically() {
    // When artifacts exist, a full fit through each backend with the same
    // seed must produce identical assignments except where f32-vs-f64
    // rounding flips a near-tie. We assert ≥99% agreement.
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut rng = Rng::seeded(77);
    let ds = mbkk::data::synthetic::blobs(
        &mbkk::data::synthetic::SyntheticSpec::new(600, 8, 4).with_separation(5.0),
        &mut rng,
    );
    let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 16.0 });
    let cfg = TruncatedConfig { k: 4, batch_size: 64, tau: 100, max_iters: 30, ..Default::default() };
    let mut native_rng = Rng::seeded(4);
    let native = TruncatedMiniBatchKernelKMeans::new(cfg.clone())
        .fit_with_backend(&gram, &mut mbkk::kkmeans::NativeBackend, &mut native_rng);
    let mut xla = mbkk::runtime::XlaBackend::load(dir).unwrap();
    let mut xla_rng = Rng::seeded(4);
    let xfit = TruncatedMiniBatchKernelKMeans::new(cfg)
        .fit_with_backend(&gram, &mut xla, &mut xla_rng);
    assert!(xla.xla_calls > 0);
    let agree = native
        .result
        .assignments
        .iter()
        .zip(xfit.result.assignments.iter())
        .filter(|(a, b)| a == b)
        .count();
    let frac = agree as f64 / ds.n as f64;
    assert!(frac > 0.99, "backend agreement only {frac}");
}
