//! Property tests for the panel micro-kernel engine and the persistent
//! worker pool (ISSUE 3).
//!
//! Two families:
//!
//! * **Panel vs scalar** — the register-tiled panel fills must agree with
//!   an independent difference-form scalar reference to ≤ 1e-6 (relative
//!   for the unbounded dot kernels) across kernel families, odd tile
//!   remainders, and d ∈ {1, 3, 16, 128}; and must agree *bit-for-bit*
//!   with the crate's own scalar `KernelFunction::eval`, which replays the
//!   panel arithmetic.
//! * **Pool vs serial** — every `par_*` helper must produce exactly the
//!   serial result, including under nested use (a parallel region whose
//!   tasks open further parallel regions), since the persistent pool
//!   replaced scoped per-call spawns.

use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::data::Dataset;
use mbkk::kernels::{Gram, KernelFunction, KernelPanel};
use mbkk::testutil::prop::{check, from_fn};
use mbkk::util::parallel;
use mbkk::util::rng::Rng;

/// Independent oracle: the pre-panel difference-form scalar kernel.
fn reference_eval(func: KernelFunction, a: &[f32], b: &[f32]) -> f64 {
    let sqdist: f64 = a
        .iter()
        .zip(b.iter())
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum();
    let dot: f64 = a
        .iter()
        .zip(b.iter())
        .map(|(x, y)| (*x as f64) * (*y as f64))
        .sum();
    match func {
        KernelFunction::Gaussian { kappa } => (-sqdist / kappa).exp(),
        KernelFunction::Laplacian { sigma } => (-sqdist.sqrt() / sigma).exp(),
        KernelFunction::Polynomial { gamma, coef0, degree } => {
            (gamma * dot + coef0).powi(degree as i32)
        }
        KernelFunction::Linear => dot,
    }
}

fn random_kernel(rng: &mut Rng) -> KernelFunction {
    match rng.below(4) {
        0 => KernelFunction::Gaussian { kappa: 0.5 + rng.f64() * 8.0 },
        1 => KernelFunction::Laplacian { sigma: 0.5 + rng.f64() * 4.0 },
        2 => KernelFunction::Polynomial {
            gamma: 0.1 + rng.f64(),
            coef0: rng.f64(),
            degree: 1 + rng.below(3) as u32,
        },
        _ => KernelFunction::Linear,
    }
}

/// Random dataset with a dimension drawn from the satellite's roster,
/// including the d = 128 case that exercises many full micro-kernel steps.
fn random_dataset(rng: &mut Rng) -> Dataset {
    let d = [1usize, 3, 16, 128][rng.below(4)];
    let n = 6 + rng.below(40);
    blobs(&SyntheticSpec::new(n, d, 1 + rng.below(3)), rng)
}

#[test]
fn prop_panel_agrees_with_difference_form_reference() {
    let gen = from_fn(|rng| {
        let ds = random_dataset(rng);
        let func = random_kernel(rng);
        // Odd shapes: force remainder rows (mod 4) and cols (mod 8).
        let rows: Vec<usize> = (0..1 + rng.below(11)).map(|_| rng.below(ds.n)).collect();
        let cols: Vec<usize> = (0..1 + rng.below(19)).map(|_| rng.below(ds.n)).collect();
        (ds, func, rows, cols)
    });
    check("panel ≤1e-6 from scalar reference", gen, |(ds, func, rows, cols)| {
        let panel = KernelPanel::new(ds, *func);
        let mut out = vec![f64::NAN; rows.len() * cols.len()];
        panel.fill_f64(rows, cols, &mut out);
        for (r, &i) in rows.iter().enumerate() {
            for (c, &j) in cols.iter().enumerate() {
                let got = out[r * cols.len() + c];
                let want = reference_eval(*func, ds.row(i), ds.row(j));
                // Relative for the unbounded dot kernels (blob features
                // push polynomial values to ~1e8), absolute ≤ 1e-6 for the
                // normalized ones.
                if (got - want).abs() > 1e-6 * want.abs().max(1.0) {
                    eprintln!("({i},{j}) {func:?}: {got} vs {want}");
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_panel_bit_identical_to_scalar_eval() {
    // The crate's scalar path replays the panel arithmetic, so agreement
    // is exact — any tile shape, any remainder, bit for bit.
    let gen = from_fn(|rng| {
        let ds = random_dataset(rng);
        let func = random_kernel(rng);
        let rows: Vec<usize> = (0..1 + rng.below(9)).map(|_| rng.below(ds.n)).collect();
        let cols: Vec<usize> = (0..1 + rng.below(17)).map(|_| rng.below(ds.n)).collect();
        (ds, func, rows, cols)
    });
    check("panel ≡ KernelFunction::eval bitwise", gen, |(ds, func, rows, cols)| {
        let panel = KernelPanel::new(ds, *func);
        let mut out = vec![f64::NAN; rows.len() * cols.len()];
        panel.fill_f64(rows, cols, &mut out);
        for (r, &i) in rows.iter().enumerate() {
            for (c, &j) in cols.iter().enumerate() {
                let got = out[r * cols.len() + c];
                if got.to_bits() != func.eval(ds.row(i), ds.row(j)).to_bits() {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_materialized_table_bit_identical_to_quantized_eval() {
    // The f32 the panel-filled table stores is exactly `eval(i,j) as f32`
    // regardless of the tile edge — the invariant the streaming cache's
    // bit-identity contract builds on.
    let gen = from_fn(|rng| {
        let ds = random_dataset(rng);
        let func = random_kernel(rng);
        let tile = 1 + rng.below(ds.n + 4);
        (ds, func, tile)
    });
    check("materialized ≡ quantized eval bitwise", gen, |(ds, func, tile)| {
        let fly = Gram::on_the_fly(ds, *func);
        let mat = fly.materialize_tiled(*tile);
        for i in 0..ds.n {
            for j in 0..ds.n {
                let stored = Gram::eval(&mat, i, j);
                let direct = (Gram::eval(&fly, i, j) as f32) as f64;
                if stored.to_bits() != direct.to_bits() {
                    eprintln!("tile={tile} ({i},{j}): {stored} vs {direct}");
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_par_helpers_match_serial() {
    let gen = from_fn(|rng| {
        let n = 1 + rng.below(4000);
        let seed = rng.next_u64();
        (n, seed)
    });
    check("pool par_* ≡ serial", gen, |&(n, seed)| {
        let mut rng = Rng::seeded(seed);
        let data: Vec<f64> = (0..n).map(|_| rng.f64() - 0.25).collect();
        // par_map_indexed
        let mapped = parallel::par_map_indexed(n, |i| data[i] * 2.0);
        for (i, v) in mapped.iter().enumerate() {
            if *v != data[i] * 2.0 {
                return false;
            }
        }
        // par_fold (chunk-ordered reduction must match the chunked serial
        // order; compare against an order-insensitive oracle with an
        // epsilon instead of demanding one global association)
        let sum = parallel::par_fold(n, 0.0f64, |i| data[i], |a, b| a + b);
        let serial: f64 = data.iter().sum();
        if (sum - serial).abs() > 1e-9 * (1.0 + serial.abs()) {
            return false;
        }
        // par_chunks_mut
        let mut out = vec![0.0f64; n];
        parallel::par_chunks_mut(&mut out, |start, chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = data[start + i] + 1.0;
            }
        });
        out.iter().zip(&data).all(|(o, d)| *o == d + 1.0)
    });
}

#[test]
fn prop_nested_parallel_regions_match_serial() {
    // Nested use with BOTH levels genuinely on the pool: par_dynamic has
    // no serial-path threshold (one task per index), so the outer tasks
    // run on pool workers and the inner folds (inner can exceed the
    // 256-item serial threshold) submit nested jobs from inside them —
    // the shape the panel engine produces when norms initialization runs
    // inside a parallel block fill.
    use std::sync::Mutex;
    let gen = from_fn(|rng| (1 + rng.below(24), 260 + rng.below(600), rng.next_u64()));
    check("nested par regions ≡ serial", gen, |&(outer, inner, seed)| {
        let mut rng = Rng::seeded(seed);
        let weights: Vec<u64> = (0..outer).map(|_| rng.below(1000) as u64).collect();
        let got: Vec<Mutex<u64>> = (0..outer).map(|_| Mutex::new(0)).collect();
        parallel::par_dynamic(outer, |o| {
            let inner_sum =
                parallel::par_fold(inner, 0u64, |i| (o as u64) * (i as u64), |a, b| a + b);
            *got[o].lock().unwrap() = weights[o] + inner_sum;
        });
        for (o, v) in got.iter().enumerate() {
            let inner_sum: u64 = (0..inner as u64).map(|i| o as u64 * i).sum();
            if *v.lock().unwrap() != weights[o] + inner_sum {
                return false;
            }
        }
        true
    });
}

#[test]
fn pool_never_respawns_threads_per_call() {
    // The acceptance criterion "no par_* call site spawns OS threads per
    // invocation", observed through ThreadIds (unique for the process
    // lifetime, never reused): across many parallel regions, the set of
    // distinct threads that ever execute a task is bounded by the pool
    // width + the submitting thread. The old scoped-spawn implementation
    // created fresh ThreadIds every region, so 60 regions would accumulate
    // dozens of distinct ids.
    use std::collections::HashSet;
    use std::sync::Mutex;
    use std::thread::ThreadId;
    let ids: Mutex<HashSet<ThreadId>> = Mutex::new(HashSet::new());
    for _ in 0..60 {
        parallel::par_dynamic(48, |_| {
            ids.lock().unwrap().insert(std::thread::current().id());
            // A little work so multiple workers participate.
            std::hint::black_box((0..500).sum::<u64>());
        });
    }
    let distinct = ids.lock().unwrap().len();
    assert!(
        distinct <= parallel::num_threads(),
        "{distinct} distinct threads executed tasks (pool width {}) — \
         parallel regions are spawning per invocation",
        parallel::num_threads()
    );
}
