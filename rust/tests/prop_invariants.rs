//! Property-based integration tests over the coordinator invariants:
//! sliding-window state, truncation error, batching/assignment, backend
//! agreement, and metric axioms. Uses the crate's own `testutil::prop`
//! harness (proptest is unavailable offline; same forall/shrink model).

use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::kernels::{Gram, KernelFunction};
use mbkk::kkmeans::backend::argmin_rows;
use mbkk::kkmeans::learning_rate::{LearningRate, RateState};
use mbkk::kkmeans::{AssignBackend, CenterWindow, NativeBackend};
use mbkk::testutil::prop::{check, from_fn, usize_in, vec_of};
use mbkk::util::rng::Rng;

fn fixture(n: usize, d: usize) -> mbkk::data::Dataset {
    let mut rng = Rng::seeded(2024);
    blobs(&SyntheticSpec::new(n, d, 3), &mut rng)
}

/// A random update stream: (alpha numerator b_j, points) pairs.
fn random_stream(rng: &mut Rng, n: usize, b: usize, len: usize) -> Vec<Vec<usize>> {
    (0..len)
        .map(|_| {
            let bj = rng.below(b) + 1;
            (0..bj).map(|_| rng.below(n)).collect()
        })
        .collect()
}

#[test]
fn prop_window_weight_sum_in_unit_interval() {
    let gen = from_fn(|rng| {
        let tau = 5 + rng.below(100);
        let b = 4 + rng.below(32);
        let stream = random_stream(rng, 500, b, 30);
        (tau, b, stream)
    });
    check("window weight sum ∈ (0, 1]", gen, |(tau, b, stream)| {
        let mut w = CenterWindow::new(0, *tau);
        let mut rate = RateState::new(LearningRate::Beta, 1);
        for pts in stream {
            let alpha = rate.alpha(0, pts.len(), *b.max(&pts.len()));
            w.apply_update(alpha.min(1.0), pts, None);
            let s = w.weight_sum();
            if !(s > 0.0 && s <= 1.0 + 1e-9) {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_window_support_bounded_by_tau_plus_b() {
    let gen = from_fn(|rng| {
        let tau = 5 + rng.below(60);
        let b = 4 + rng.below(24);
        let stream = random_stream(rng, 300, b, 50);
        (tau, b, stream)
    });
    check("support ≤ τ+b+1 always", gen, |(tau, b, stream)| {
        let mut w = CenterWindow::new(0, *tau);
        for pts in stream {
            w.apply_update((pts.len() as f64 / *b as f64).min(1.0).sqrt(), pts, None);
            if w.support_len() > tau + b + 1 {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_truncation_error_obeys_lemma3() {
    // β rate + τ from Lemma 3 ⇒ ‖Ĉ−C‖ ≤ ε/28 for every prefix of every
    // random stream (γ = 1: Gaussian kernel).
    let ds = fixture(400, 4);
    let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 8.0 });
    let gen = from_fn(|rng| {
        let b = 8 + rng.below(24);
        let eps = 0.2 + rng.f64() * 2.0;
        let stream = random_stream(rng, 400, b, 40);
        (b, eps, stream)
    });
    check("Lemma 3 truncation bound", gen, |(b, eps, stream)| {
        let tau = CenterWindow::lemma3_tau(*b, 1.0, *eps);
        let mut exact = CenterWindow::new(0, usize::MAX);
        let mut trunc = CenterWindow::new(0, tau);
        let mut rate = RateState::new(LearningRate::Beta, 1);
        for pts in stream {
            let alpha = rate.alpha(0, pts.len().min(*b), *b);
            exact.apply_update(alpha, pts, None);
            trunc.apply_update(alpha, pts, None);
            let err = trunc.sqdist_to(&exact, &gram).sqrt();
            if err > eps / 28.0 + 1e-9 {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_assignment_partition_covers_batch() {
    // argmin_rows yields exactly one cluster per batch point, min dist
    // matches the row minimum, and permuting centers permutes assignments.
    let ds = fixture(300, 4);
    let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 8.0 });
    let gen = from_fn(|rng| {
        let k = 2 + rng.below(5);
        let batch: Vec<usize> = (0..16 + rng.below(48)).map(|_| rng.below(300)).collect();
        let seeds: Vec<usize> = (0..k).map(|_| rng.below(300)).collect();
        (batch, seeds)
    });
    check("assignment partition + permutation equivariance", gen, |(batch, seeds)| {
        let k = seeds.len();
        let mut centers: Vec<CenterWindow> =
            seeds.iter().map(|&s| CenterWindow::new(s, 50)).collect();
        let dist = NativeBackend.distances(&gram, batch, &mut centers);
        let (assign, mins) = argmin_rows(&dist, k);
        if assign.len() != batch.len() {
            return false;
        }
        for (r, (&a, &m)) in assign.iter().zip(mins.iter()).enumerate() {
            let row = &dist[r * k..(r + 1) * k];
            if a >= k || (row[a] - m).abs() > 1e-12 {
                return false;
            }
            if row.iter().any(|&v| v < m - 1e-12) {
                return false;
            }
        }
        // Reverse the centers: assignments must mirror (ties may flip among
        // equal distances; skip rows with near-ties).
        let mut rev: Vec<CenterWindow> = seeds
            .iter()
            .rev()
            .map(|&s| CenterWindow::new(s, 50))
            .collect();
        let dist_r = NativeBackend.distances(&gram, batch, &mut rev);
        let (assign_r, _) = argmin_rows(&dist_r, k);
        for (r, &a) in assign.iter().enumerate() {
            let row = &dist[r * k..(r + 1) * k];
            let sorted = {
                let mut s: Vec<f64> = row.to_vec();
                s.sort_by(|x, y| x.partial_cmp(y).unwrap());
                s
            };
            let tie = sorted.len() > 1 && (sorted[1] - sorted[0]).abs() < 1e-9;
            if !tie && assign_r[r] != k - 1 - a {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_ari_nmi_axioms() {
    use mbkk::metrics::{ari, nmi};
    let gen = vec_of(usize_in(0..4), 8..60);
    check("ARI/NMI axioms (identity, symmetry, bounds)", gen, |labels| {
        if labels.is_empty() {
            return true;
        }
        let a = ari(labels, labels);
        let n = nmi(labels, labels);
        if (a - 1.0).abs() > 1e-9 || (n - 1.0).abs() > 1e-9 {
            return false;
        }
        // Relabeled copy still perfect.
        let relabeled: Vec<usize> = labels.iter().map(|&l| (l + 1) % 4).collect();
        if (ari(labels, &relabeled) - 1.0).abs() > 1e-9 {
            return false;
        }
        // Symmetry + bounds against a shifted variant.
        let other: Vec<usize> = labels.iter().rev().copied().collect();
        let ab = ari(labels, &other);
        let ba = ari(&other, labels);
        (ab - ba).abs() < 1e-9 && nmi(labels, &other) <= 1.0 + 1e-9 && ab <= 1.0 + 1e-9
    });
}

#[test]
fn prop_weighted_update_reduces_to_uniform_when_weights_equal() {
    let ds = fixture(200, 4);
    let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 8.0 });
    let gen = from_fn(|rng| random_stream(rng, 200, 16, 12));
    check("uniform weights ≡ unweighted", gen, |stream| {
        let mut a = CenterWindow::new(0, 40);
        let mut b = CenterWindow::new(0, 40);
        for pts in stream {
            let alpha = (pts.len() as f64 / 16.0).min(1.0).sqrt();
            a.apply_update(alpha, pts, None);
            let w = vec![2.5; pts.len()];
            b.apply_update(alpha, pts, Some(&w));
        }
        (a.self_inner(&gram) - b.self_inner(&gram)).abs() < 1e-9
            && (a.weight_sum() - b.weight_sum()).abs() < 1e-9
    });
}

#[test]
fn prop_sklearn_rate_decays_beta_does_not() {
    let gen = from_fn(|rng| {
        let b = 8 + rng.below(64);
        let bjs: Vec<usize> = (0..20).map(|_| 1 + rng.below(b)).collect();
        (b, bjs)
    });
    check("learning-rate schedules", gen, |(b, bjs)| {
        let mut skl = RateState::new(LearningRate::Sklearn, 1);
        let mut beta = RateState::new(LearningRate::Beta, 1);
        let mut last_skl = 1.0f64;
        for &bj in bjs {
            let a_s = skl.alpha(0, bj, *b);
            let a_b = beta.alpha(0, bj, *b);
            // β is memoryless: exact closed form.
            if (a_b - (bj as f64 / *b as f64).sqrt()).abs() > 1e-12 {
                return false;
            }
            // sklearn: strictly decaying upper envelope bj/(counts) < 1,
            // and bounded by previous alpha when bj is fixed... use the
            // weaker sound property: α ∈ (0,1) and cumulative denominator
            // monotonicity ⇒ α_i < 1 always and final α < first α when all
            // bj equal.
            if !(a_s > 0.0 && a_s < 1.0) {
                return false;
            }
            last_skl = a_s;
        }
        let _ = last_skl;
        true
    });
}
