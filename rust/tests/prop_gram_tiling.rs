//! Property tests for the tiled gram engine (DESIGN.md §5): randomized
//! shapes, kernels, and tile widths must never change the numbers —
//! materialization agrees with on-the-fly evaluation entry-wise,
//! materialized matrices are exactly symmetric, and the tiled `K(B, S)`
//! block/contraction paths match naive double loops.

use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::kernels::{Gram, KernelFunction};
use mbkk::testutil::prop::{check, from_fn};
use mbkk::util::rng::Rng;

/// Relative closeness against f32 gram storage: polynomial/linear kernels
/// on raw blob features reach 1e8, where f32 rounding alone is ~10, so
/// tolerances must scale with magnitude.
fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-4 * a.abs().max(b.abs()).max(1.0)
}

/// A random (dataset, kernel) pair small enough for O(n²) oracles.
fn random_case(rng: &mut Rng) -> (mbkk::data::Dataset, KernelFunction) {
    let n = 8 + rng.below(40);
    let d = 1 + rng.below(6);
    let k = 1 + rng.below(4);
    let ds = blobs(&SyntheticSpec::new(n, d, k), rng);
    let func = match rng.below(4) {
        0 => KernelFunction::Gaussian { kappa: 0.5 + rng.f64() * 8.0 },
        1 => KernelFunction::Laplacian { sigma: 0.5 + rng.f64() * 4.0 },
        2 => KernelFunction::Polynomial {
            gamma: 0.1 + rng.f64(),
            coef0: rng.f64(),
            degree: 1 + rng.below(3) as u32,
        },
        _ => KernelFunction::Linear,
    };
    (ds, func)
}

#[test]
fn prop_materialize_agrees_entrywise_for_any_tile() {
    let gen = from_fn(|rng| {
        let (ds, func) = random_case(rng);
        let tile = 1 + rng.below(ds.n + 8);
        (ds, func, tile)
    });
    check("materialize ≡ on-the-fly entry-wise", gen, |(ds, func, tile)| {
        let fly = Gram::on_the_fly(ds, *func);
        let mat = fly.materialize_tiled(*tile);
        for i in 0..ds.n {
            for j in 0..ds.n {
                if !close(fly.eval(i, j), mat.eval(i, j)) {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_materialized_matrix_is_symmetric_with_correct_diagonal() {
    let gen = from_fn(|rng| {
        let (ds, func) = random_case(rng);
        let tile = 1 + rng.below(ds.n + 8);
        (ds, func, tile)
    });
    check("materialized gram symmetric + diag", gen, |(ds, func, tile)| {
        let fly = Gram::on_the_fly(ds, *func);
        let mat = fly.materialize_tiled(*tile);
        for i in 0..ds.n {
            // Mirrored writes make symmetry bit-exact, not just approximate.
            for j in 0..ds.n {
                if mat.eval(i, j) != mat.eval(j, i) {
                    return false;
                }
            }
            if !close(mat.self_k(i), fly.self_k(i)) {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_tiled_block_matches_naive_double_loop() {
    let gen = from_fn(|rng| {
        let (ds, func) = random_case(rng);
        let n = ds.n;
        let rows: Vec<usize> = (0..1 + rng.below(20)).map(|_| rng.below(n)).collect();
        let cols: Vec<usize> = (0..1 + rng.below(30)).map(|_| rng.below(n)).collect();
        let tile = 1 + rng.below(cols.len() + 4);
        (ds, func, rows, cols, tile)
    });
    check(
        "tiled K(B,S) block ≡ naive double loop",
        gen,
        |(ds, func, rows, cols, tile)| {
            let fly = Gram::on_the_fly(ds, *func);
            let mat = fly.materialize();
            for gram in [&fly, &mat] {
                let mut out = vec![f64::NAN; rows.len() * cols.len()];
                gram.block_into_tiled(rows, cols, *tile, &mut out);
                for (r, &i) in rows.iter().enumerate() {
                    for (c, &j) in cols.iter().enumerate() {
                        let want = fly.eval(i, j);
                        if !close(out[r * cols.len() + c], want) {
                            return false;
                        }
                    }
                }
            }
            true
        },
    );
}

#[test]
fn prop_weighted_cross_matches_naive_contraction() {
    // The fused K(B,S)·w engine against an explicit two-loop oracle, over
    // random center counts, support sizes (including empty), and weights.
    let gen = from_fn(|rng| {
        let (ds, func) = random_case(rng);
        let n = ds.n;
        let k = 1 + rng.below(5);
        let batch: Vec<usize> = (0..1 + rng.below(24)).map(|_| rng.below(n)).collect();
        let mut sup_idx: Vec<u32> = Vec::new();
        let mut sup_w: Vec<f64> = Vec::new();
        let mut ranges: Vec<(usize, usize)> = Vec::new();
        for _ in 0..k {
            let start = sup_idx.len();
            for _ in 0..rng.below(30) {
                sup_idx.push(rng.below(n) as u32);
                sup_w.push(rng.f64() * 2.0 - 0.5);
            }
            ranges.push((start, sup_idx.len()));
        }
        (ds, func, batch, sup_idx, sup_w, ranges)
    });
    check(
        "weighted_cross_into ≡ naive Σ w·K",
        gen,
        |(ds, func, batch, sup_idx, sup_w, ranges)| {
            let fly = Gram::on_the_fly(ds, *func);
            let mat = fly.materialize();
            let k = ranges.len();
            for gram in [&fly, &mat] {
                let mut out = vec![f64::NAN; batch.len() * k];
                gram.weighted_cross_into(batch, sup_idx, sup_w, ranges, &mut out);
                for (r, &x) in batch.iter().enumerate() {
                    for (j, &(s, e)) in ranges.iter().enumerate() {
                        let want: f64 = (s..e)
                            .map(|m| sup_w[m] * fly.eval(x, sup_idx[m] as usize))
                            .sum();
                        // Mixed-sign weights can cancel, so scale the
                        // tolerance by the magnitude sum, not the result.
                        let scale: f64 = (s..e)
                            .map(|m| (sup_w[m] * fly.eval(x, sup_idx[m] as usize)).abs())
                            .sum();
                        if (out[r * k + j] - want).abs() > 1e-4 * scale.max(1.0) {
                            return false;
                        }
                    }
                }
            }
            true
        },
    );
}
