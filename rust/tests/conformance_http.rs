//! HTTP serving conformance suite (ISSUE 7): every endpoint documented in
//! docs/API.md, exercised over real sockets.
//!
//! Contract under test (DESIGN.md §11, docs/API.md):
//!
//! 1. `POST /v1/predict` answers assignments **bit-identical** to the
//!    scalar `KernelKMeansModel::predict` for the same feature text,
//!    across request mixes (1/7/64 rows) and client thread counts — and
//!    coalesced results equal sequential per-request results.
//! 2. Under synchronized concurrent load the admission queue actually
//!    coalesces: the served-batches counter stays below the request
//!    counter (the CI `e2e-http` assertion, pinned here in-process).
//! 3. Malformed JSON, truncated bodies, oversized payloads, and missing
//!    `Content-Length` all answer documented error envelopes — the
//!    connection never dies unannounced and the server never panics.
//! 4. `/healthz` and `/v1/models` response shapes are pinned.
//! 5. `serve::format` loader errors name the offending artifact path
//!    (the ISSUE 7 bugfix regression).

use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::data::Dataset;
use mbkk::kernels::KernelFunction;
use mbkk::kkmeans::{CenterWindow, KernelKMeansModel};
use mbkk::serve::coalesce::StatsSnapshot;
use mbkk::serve::http::{ServeConfig, Server};
use mbkk::util::json::Json;
use mbkk::util::rng::Rng;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Duration;

// ---- fixtures -------------------------------------------------------------

/// A small servable model (the conformance_serve idiom: irregular support
/// sizes without paying for a full fit).
fn model_for(d: usize, seed: u64) -> (Dataset, KernelKMeansModel) {
    let mut rng = Rng::seeded(seed);
    let ds = blobs(&SyntheticSpec::new(160, d, 3), &mut rng);
    let mut windows: Vec<CenterWindow> =
        (0..3).map(|j| CenterWindow::new(j * 7, 23)).collect();
    for step in 0..12 {
        for (j, w) in windows.iter_mut().enumerate() {
            let pts: Vec<usize> =
                (0..1 + (step + j) % 5).map(|_| rng.below(ds.n)).collect();
            w.apply_update(0.4, &pts, None);
        }
    }
    let model =
        KernelKMeansModel::freeze(&ds, KernelFunction::Gaussian { kappa: 2.0 }, &mut windows);
    (ds, model)
}

struct TestServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<mbkk::util::error::Result<StatsSnapshot>>,
}

fn start_server(model: &KernelKMeansModel, tweak: impl FnOnce(&mut ServeConfig)) -> TestServer {
    let mut cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        max_wait: Duration::from_millis(5),
        max_batch_rows: 512,
        max_body_bytes: 256 * 1024,
        read_timeout: Duration::from_millis(400),
        max_connections: 64,
        request_deadline: Duration::from_secs(5),
        numerics: mbkk::kernels::NumericsMode::Deterministic,
        ..ServeConfig::default()
    };
    tweak(&mut cfg);
    let server = Server::bind(model, "test-model.mbkk", &cfg).expect("bind");
    let addr = server.local_addr().expect("local addr");
    let shutdown = server.shutdown_flag();
    let handle = std::thread::spawn(move || server.run());
    TestServer { addr, shutdown, handle }
}

impl TestServer {
    /// Flip the shutdown flag and collect the final counters — the same
    /// clean-shutdown path SIGTERM takes in `mbkk serve`.
    fn stop(self) -> StatsSnapshot {
        self.shutdown.store(true, Ordering::SeqCst);
        self.handle.join().expect("server thread").expect("server run")
    }
}

// ---- a tiny blocking HTTP client ------------------------------------------

struct Resp {
    status: u16,
    body: Json,
    close: bool,
    allow: Option<String>,
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let s = TcpStream::connect(addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        Client { reader: BufReader::new(s.try_clone().unwrap()), writer: s }
    }

    fn send_raw(&mut self, raw: &[u8]) {
        self.writer.write_all(raw).expect("send");
    }

    fn request(&mut self, method: &str, path: &str, body: Option<&str>) -> Resp {
        let mut req = format!("{method} {path} HTTP/1.1\r\nHost: test\r\n");
        if let Some(b) = body {
            req.push_str(&format!("Content-Length: {}\r\n", b.len()));
        }
        req.push_str("\r\n");
        if let Some(b) = body {
            req.push_str(b);
        }
        self.send_raw(req.as_bytes());
        self.read_response()
    }

    fn read_response(&mut self) -> Resp {
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("status line");
        assert!(line.starts_with("HTTP/1.1 "), "bad status line {line:?}");
        let status: u16 = line.split_whitespace().nth(1).expect("code").parse().expect("code");
        let mut len = 0usize;
        let mut close = false;
        let mut allow = None;
        loop {
            let mut h = String::new();
            self.reader.read_line(&mut h).expect("header line");
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            let (name, value) = h.split_once(':').expect("header colon");
            match name.to_ascii_lowercase().as_str() {
                "content-length" => len = value.trim().parse().expect("length"),
                "connection" if value.trim().eq_ignore_ascii_case("close") => close = true,
                "allow" => allow = Some(value.trim().to_string()),
                _ => {}
            }
        }
        let mut body = vec![0u8; len];
        self.reader.read_exact(&mut body).expect("body");
        let body = Json::parse(std::str::from_utf8(&body).expect("utf8")).expect("json body");
        Resp { status, body, close, allow }
    }
}

/// Serialize rows the way a client would: shortest-round-trip f32 text
/// (`format!("{v}")`), which `parse::<f32>` recovers bit-exactly.
fn points_json(ds: &Dataset, idx: &[usize]) -> String {
    let rows: Vec<String> = idx
        .iter()
        .map(|&i| {
            let cells: Vec<String> = ds.row(i).iter().map(|v| format!("{v}")).collect();
            format!("[{}]", cells.join(","))
        })
        .collect();
    format!("{{\"points\": [{}]}}", rows.join(","))
}

fn assignments_of(resp: &Resp) -> Vec<usize> {
    resp.body
        .get("assignments")
        .as_arr()
        .expect("assignments array")
        .iter()
        .map(|v| v.as_usize().expect("assignment index"))
        .collect()
}

// ---- endpoint shape pins --------------------------------------------------

#[test]
fn healthz_and_models_shapes() {
    let (_ds, model) = model_for(6, 41);
    let srv = start_server(&model, |_| {});
    let mut c = Client::connect(srv.addr);

    let health = c.request("GET", "/healthz", None);
    assert_eq!(health.status, 200);
    assert_eq!(health.body.get("status").as_str(), Some("ok"));
    assert_eq!(health.body.get("model").get("name").as_str(), Some("test-model.mbkk"));
    assert_eq!(health.body.get("model").get("k").as_usize(), Some(model.k()));
    assert_eq!(health.body.get("model").get("d").as_usize(), Some(model.d));
    let stats = health.body.get("stats");
    for key in [
        "requests", "batches", "rows", "coalesced_batches", "max_batch_rows",
        "aborted_requests", "shed_requests",
    ] {
        assert!(stats.get(key).as_f64().is_some(), "stats missing {key}");
    }
    assert!(stats.get("active_connections").as_usize().is_some());

    // Query strings are stripped before routing.
    assert_eq!(c.request("GET", "/healthz?verbose=1", None).status, 200);

    let models = c.request("GET", "/v1/models", None);
    assert_eq!(models.status, 200);
    let entries = models.body.get("models").as_arr().expect("models array");
    assert_eq!(entries.len(), 1);
    let m = &entries[0];
    assert_eq!(m.get("name").as_str(), Some("test-model.mbkk"));
    assert_eq!(m.get("kind").as_str(), Some("model"));
    assert_eq!(m.get("format_version").as_usize(), Some(mbkk::serve::format::FORMAT_VERSION));
    assert_eq!(m.get("kernel").get("name").as_str(), Some("gaussian"));
    assert!(m.get("kernel").get("kappa").as_f64().is_some());
    assert_eq!(m.get("k").as_usize(), Some(model.k()));
    assert_eq!(m.get("d").as_usize(), Some(model.d));
    assert_eq!(m.get("support_points").as_usize(), Some(model.support_points()));

    srv.stop();
}

// ---- bit-identity ---------------------------------------------------------

#[test]
fn predict_matches_scalar_bitwise_across_mixes() {
    let (ds, model) = model_for(8, 42);
    let srv = start_server(&model, |_| {});
    let mut c = Client::connect(srv.addr);

    // Mixes cover 1-row, odd, and beyond-one-panel request sizes.
    for (start, rows) in [(0usize, 1usize), (3, 7), (11, 64)] {
        let idx: Vec<usize> = (0..rows).map(|j| (start + j * 3) % ds.n).collect();
        let resp = c.request("POST", "/v1/predict", Some(&points_json(&ds, &idx)));
        assert_eq!(resp.status, 200, "{:?}", resp.body.to_string());
        assert_eq!(resp.body.get("rows").as_usize(), Some(rows));
        let got = assignments_of(&resp);
        let want: Vec<usize> = idx.iter().map(|&i| model.predict(ds.row(i))).collect();
        assert_eq!(got, want, "served assignments diverged from scalar predict");
    }

    // Empty batch: well-formed, zero rows, zero assignments.
    let resp = c.request("POST", "/v1/predict", Some("{\"points\": []}"));
    assert_eq!(resp.status, 200);
    assert_eq!(resp.body.get("rows").as_usize(), Some(0));
    assert!(assignments_of(&resp).is_empty());

    srv.stop();
}

#[test]
fn coalesced_equals_sequential_across_thread_counts() {
    let (ds, model) = model_for(5, 43);
    let ds = Arc::new(ds);
    let model = Arc::new(model);
    for threads in [2usize, 8] {
        let srv = start_server(model.as_ref(), |cfg| cfg.max_wait = Duration::from_millis(100));
        let rounds = 3usize;
        let barrier = Arc::new(Barrier::new(threads));
        let mut handles = Vec::new();
        for t in 0..threads {
            let ds = Arc::clone(&ds);
            let model = Arc::clone(&model);
            let barrier = Arc::clone(&barrier);
            let addr = srv.addr;
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                for r in 0..rounds {
                    let rows = 1 + (t + r) % 4;
                    let idx: Vec<usize> =
                        (0..rows).map(|j| (t * 31 + r * 7 + j) % ds.n).collect();
                    let body = points_json(&ds, &idx);
                    // Rendezvous so every thread's request hits the same
                    // coalescing window.
                    barrier.wait();
                    let resp = c.request("POST", "/v1/predict", Some(&body));
                    assert_eq!(resp.status, 200);
                    let got = assignments_of(&resp);
                    let want: Vec<usize> =
                        idx.iter().map(|&i| model.predict(ds.row(i))).collect();
                    assert_eq!(got, want, "thread {t} round {r} diverged under coalescing");
                }
            }));
        }
        for h in handles {
            h.join().expect("client thread");
        }
        let stats = srv.stop();
        let requests = (threads * rounds) as u64;
        assert_eq!(stats.requests, requests);
        assert!(
            stats.batches < stats.requests,
            "no coalescing at {threads} threads: {stats:?}"
        );
        assert!(stats.coalesced_batches >= 1, "{stats:?}");
        assert_eq!(stats.rows, {
            let mut total = 0u64;
            for t in 0..threads {
                for r in 0..rounds {
                    total += (1 + (t + r) % 4) as u64;
                }
            }
            total
        });
    }
}

// ---- robustness: the never-panic error envelope ---------------------------

#[test]
fn malformed_json_gets_400_and_connection_survives() {
    let (ds, model) = model_for(4, 44);
    let srv = start_server(&model, |_| {});
    let mut c = Client::connect(srv.addr);

    for (bad, code) in [
        ("{not json", "invalid_json"),
        ("[1, 2, 3]", "invalid_json"),
        ("{\"rows\": []}", "missing_field"),
        ("{\"points\": [[1, 2], [3]]}", "invalid_points"),
        ("{\"points\": [[\"a\"]]}", "invalid_points"),
        ("{\"points\": 7}", "invalid_points"),
    ] {
        let resp = c.request("POST", "/v1/predict", Some(bad));
        assert_eq!(resp.status, 400, "{bad}");
        assert_eq!(resp.body.get("error").get("code").as_str(), Some(code), "{bad}");
        assert!(resp.body.get("error").get("message").as_str().is_some());
        assert!(!resp.close, "body-level 400 must keep the connection open ({bad})");
    }

    // Shape mismatch against the served model's dimension.
    let resp = c.request("POST", "/v1/predict", Some("{\"points\": [[1, 2]]}"));
    assert_eq!(resp.status, 400);
    assert_eq!(resp.body.get("error").get("code").as_str(), Some("shape_mismatch"));

    // The same connection still serves a good request afterwards.
    let idx = vec![0usize, 1];
    let resp = c.request("POST", "/v1/predict", Some(&points_json(&ds, &idx)));
    assert_eq!(resp.status, 200);

    srv.stop();
}

#[test]
fn truncated_body_gets_400_then_close() {
    let (_ds, model) = model_for(4, 45);
    let srv = start_server(&model, |_| {});
    let mut c = Client::connect(srv.addr);
    // Advertise 100 bytes, send 10, then half-close: the server sees EOF
    // mid-body and must answer 400 instead of hanging or panicking.
    c.send_raw(b"POST /v1/predict HTTP/1.1\r\nHost: t\r\nContent-Length: 100\r\n\r\n{\"points\"");
    c.writer.shutdown(Shutdown::Write).unwrap();
    let resp = c.read_response();
    assert_eq!(resp.status, 400);
    assert_eq!(resp.body.get("error").get("code").as_str(), Some("bad_request"));
    assert!(resp.close, "framing is lost after a truncated body; must close");
    srv.stop();
}

#[test]
fn stalled_body_times_out_with_400() {
    let (_ds, model) = model_for(4, 46);
    let srv = start_server(&model, |cfg| cfg.read_timeout = Duration::from_millis(150));
    let mut c = Client::connect(srv.addr);
    // Advertise a body and never send it (connection stays open): the
    // socket read timeout converts the stall into a 400.
    c.send_raw(b"POST /v1/predict HTTP/1.1\r\nHost: t\r\nContent-Length: 50\r\n\r\n");
    let resp = c.read_response();
    assert_eq!(resp.status, 400);
    assert!(resp.close);
    srv.stop();
}

#[test]
fn oversized_payload_gets_413_without_reading_it() {
    let (_ds, model) = model_for(4, 47);
    let srv = start_server(&model, |cfg| cfg.max_body_bytes = 1024);
    let mut c = Client::connect(srv.addr);
    // 10 MiB advertised, zero bytes sent: the 413 must come back
    // immediately, proving the server rejected on the header alone.
    c.send_raw(b"POST /v1/predict HTTP/1.1\r\nHost: t\r\nContent-Length: 10485760\r\n\r\n");
    let resp = c.read_response();
    assert_eq!(resp.status, 413);
    assert_eq!(resp.body.get("error").get("code").as_str(), Some("payload_too_large"));
    assert!(resp.close);
    srv.stop();
}

#[test]
fn missing_content_length_gets_411() {
    let (_ds, model) = model_for(4, 48);
    let srv = start_server(&model, |_| {});
    let mut c = Client::connect(srv.addr);
    let resp = c.request("POST", "/v1/predict", None);
    assert_eq!(resp.status, 411);
    assert_eq!(resp.body.get("error").get("code").as_str(), Some("length_required"));
    assert!(resp.close);
    srv.stop();
}

#[test]
fn unknown_routes_and_methods() {
    let (_ds, model) = model_for(4, 49);
    let srv = start_server(&model, |_| {});
    let mut c = Client::connect(srv.addr);

    let resp = c.request("GET", "/nope", None);
    assert_eq!(resp.status, 404);
    assert_eq!(resp.body.get("error").get("code").as_str(), Some("not_found"));

    let resp = c.request("DELETE", "/healthz", None);
    assert_eq!(resp.status, 405);
    assert_eq!(resp.allow.as_deref(), Some("GET"));

    let resp = c.request("GET", "/v1/predict", None);
    assert_eq!(resp.status, 405);
    assert_eq!(resp.allow.as_deref(), Some("POST"));

    srv.stop();
}

#[test]
fn expect_continue_is_acknowledged() {
    let (ds, model) = model_for(4, 50);
    let srv = start_server(&model, |_| {});
    let mut c = Client::connect(srv.addr);
    let body = points_json(&ds, &[0, 1, 2]);
    c.send_raw(
        format!(
            "POST /v1/predict HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\
             Expect: 100-continue\r\n\r\n",
            body.len()
        )
        .as_bytes(),
    );
    // The interim response arrives before we send a single body byte —
    // without it curl would stall ~1 s per request and wreck p99.
    let mut line = String::new();
    c.reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("HTTP/1.1 100 Continue"), "{line:?}");
    let mut blank = String::new();
    c.reader.read_line(&mut blank).unwrap();
    c.send_raw(body.as_bytes());
    let resp = c.read_response();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.body.get("rows").as_usize(), Some(3));
    srv.stop();
}

#[test]
fn clean_shutdown_returns_final_stats() {
    let (ds, model) = model_for(4, 51);
    let srv = start_server(&model, |_| {});
    let mut c = Client::connect(srv.addr);
    let resp = c.request("POST", "/v1/predict", Some(&points_json(&ds, &[0])));
    assert_eq!(resp.status, 200);
    let stats = srv.stop();
    assert_eq!(stats.requests, 1);
    assert_eq!(stats.batches, 1);
    assert_eq!(stats.rows, 1);
    // A graceful drain finishes in-flight work instead of aborting it:
    // every admitted request above was answered, so nothing was dropped
    // at the drain deadline (the SIGTERM contract in docs/API.md).
    assert_eq!(stats.aborted_requests, 0, "graceful shutdown aborted work: {stats:?}");
}

// ---- the ISSUE 7 loader-path bugfix regression ----------------------------

#[test]
fn loader_errors_name_the_artifact_path() {
    let dir = std::env::temp_dir();
    let missing = dir.join(format!("mbkk_http_missing_{}.mbkk", std::process::id()));
    let err = KernelKMeansModel::load(&missing).unwrap_err().to_string();
    assert!(err.contains(&missing.display().to_string()), "missing-file error lost path: {err}");

    let corrupt = dir.join(format!("mbkk_http_corrupt_{}.mbkk", std::process::id()));
    std::fs::write(&corrupt, b"MBKKMDL\0 but then garbage").unwrap();
    let err = KernelKMeansModel::load(&corrupt).unwrap_err().to_string();
    std::fs::remove_file(&corrupt).ok();
    assert!(err.contains(&corrupt.display().to_string()), "decode error lost path: {err}");

    let err = mbkk::serve::format::load_stream(&missing).unwrap_err().to_string();
    assert!(err.contains(&missing.display().to_string()), "stream error lost path: {err}");
}
