//! Serving conformance suite (ISSUE 4): pins the persistence format and
//! the batched prediction engine end to end.
//!
//! Contract under test (DESIGN.md §8):
//!
//! 1. save → load → predict is **bit-identical** to the in-memory model —
//!    the artifact round trip changes no byte of the model and no bit of
//!    any distance.
//! 2. Batched [`PredictEngine`] output is **bit-identical** to scalar
//!    [`KernelKMeansModel::predict`] across d ∈ {1, 3, 16, 128} and odd
//!    batch remainders (the 4-row block's tail and the 8-wide panel's
//!    padding lanes).
//! 3. Corrupted, truncated, or wrong-version artifacts fail with clear
//!    errors — never a panic, at any truncation point.

use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::data::Dataset;
use mbkk::kernels::KernelFunction;
use mbkk::kkmeans::{CenterWindow, KernelKMeansModel, LearningRate, StreamingKernelKMeans};
use mbkk::serve::PredictEngine;
use mbkk::util::rng::Rng;
use std::path::PathBuf;

/// A model with irregular per-center support sizes (entry boundaries do
/// not align with the 8-wide panels) without paying for a full fit.
fn model_for(d: usize, kernel: KernelFunction, seed: u64) -> (Dataset, KernelKMeansModel) {
    let mut rng = Rng::seeded(seed);
    let ds = blobs(&SyntheticSpec::new(80, d, 3), &mut rng);
    let mut windows: Vec<CenterWindow> =
        (0..3).map(|j| CenterWindow::new(j * 7, 23)).collect();
    for step in 0..12 {
        for (j, w) in windows.iter_mut().enumerate() {
            let pts: Vec<usize> =
                (0..1 + (step + j) % 5).map(|_| rng.below(ds.n)).collect();
            w.apply_update(0.4, &pts, None);
        }
    }
    let model = KernelKMeansModel::freeze(&ds, kernel, &mut windows);
    (ds, model)
}

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("mbkk_conformance_{tag}_{}.mbkk", std::process::id()))
}

#[test]
fn save_load_predict_is_bit_identical() {
    for (i, kernel) in [
        KernelFunction::Gaussian { kappa: 9.0 },
        KernelFunction::Laplacian { sigma: 2.0 },
        KernelFunction::Polynomial { gamma: 0.5, coef0: 1.0, degree: 3 },
        KernelFunction::Linear,
    ]
    .into_iter()
    .enumerate()
    {
        let (ds, model) = model_for(6, kernel, 11 + i as u64);
        let path = tmp_path(&format!("roundtrip_{i}"));
        model.save(&path).expect("save");
        let loaded = KernelKMeansModel::load(&path).expect("load");
        std::fs::remove_file(&path).ok();

        // The artifact round trip preserves the model byte-for-byte...
        assert_eq!(loaded.to_bytes(), model.to_bytes(), "{kernel:?}");
        assert_eq!(loaded.kernel, model.kernel);
        assert_eq!(loaded.d, model.d);
        assert_eq!(loaded.k(), model.k());
        assert_eq!(loaded.support_points(), model.support_points());

        // ...and therefore every distance and assignment bit-for-bit.
        for q in 0..ds.n {
            let a = model.distances(ds.row(q));
            let b = loaded.distances(ds.row(q));
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{kernel:?} q={q}");
            }
            assert_eq!(model.predict(ds.row(q)), loaded.predict(ds.row(q)));
        }
    }
}

#[test]
fn batched_engine_is_bit_identical_to_scalar_predict() {
    for d in [1usize, 3, 16, 128] {
        let kernel = KernelFunction::Gaussian { kappa: d as f64 + 3.0 };
        let (ds, model) = model_for(d, kernel, 31);
        let engine = PredictEngine::new(&model);
        assert_eq!(engine.k(), model.k());
        assert_eq!(engine.d(), model.d);
        // Odd remainders around the engine's 4-row blocks, including a
        // batch bigger than one parallel chunk threshold.
        for nq in [1usize, 2, 3, 4, 5, 7, 13, 80] {
            let rows = &ds.features[..nq.min(ds.n) * d];
            let nq = rows.len() / d;
            let dist = engine.distances_batch(rows);
            let pred = engine.predict_batch(rows);
            for q in 0..nq {
                let query = &rows[q * d..(q + 1) * d];
                let want = model.distances(query);
                for (j, w) in want.iter().enumerate() {
                    assert_eq!(
                        dist[q * model.k() + j].to_bits(),
                        w.to_bits(),
                        "d={d} nq={nq} q={q} j={j}"
                    );
                }
                assert_eq!(pred[q], model.predict(query), "d={d} nq={nq} q={q}");
            }
        }
    }
}

#[test]
fn engine_on_loaded_model_matches_engine_on_original() {
    let (ds, model) = model_for(16, KernelFunction::Gaussian { kappa: 20.0 }, 5);
    let loaded = KernelKMeansModel::from_bytes(&model.to_bytes()).expect("round trip");
    let a = PredictEngine::new(&model).predict_dataset(&ds);
    let b = PredictEngine::new(&loaded).predict_dataset(&ds);
    assert_eq!(a, b);
}

#[test]
fn corrupted_artifacts_error_and_never_panic() {
    let (_, model) = model_for(4, KernelFunction::Gaussian { kappa: 5.0 }, 17);
    let good = model.to_bytes();

    // Bad magic.
    let mut bad = good.clone();
    bad[0] ^= 0x55;
    let err = KernelKMeansModel::from_bytes(&bad).unwrap_err();
    assert!(format!("{err}").contains("magic"), "{err}");

    // Garbage header bytes of the same length (invalid JSON).
    let hlen = u32::from_le_bytes([good[8], good[9], good[10], good[11]]) as usize;
    let mut garbage = good.clone();
    for b in garbage[12..12 + hlen].iter_mut() {
        *b = b'#';
    }
    let err = KernelKMeansModel::from_bytes(&garbage).unwrap_err();
    assert!(format!("{err}").contains("JSON"), "{err}");

    // A flipped payload byte is a checksum mismatch under format v2
    // (v1 had to accept it — floats are opaque bytes).
    let mut flipped = good.clone();
    let last = flipped.len() - 5; // inside the payload, before the CRC tail
    flipped[last] ^= 0x01;
    let err = KernelKMeansModel::from_bytes(&flipped).unwrap_err();
    assert!(format!("{err}").contains("checksum"), "{err}");

    // A removed payload byte must be caught too.
    let mut short = good.clone();
    short.pop();
    let err = KernelKMeansModel::from_bytes(&short).unwrap_err();
    assert!(
        format!("{err}").contains("truncated") || format!("{err}").contains("corrupt"),
        "{err}"
    );

    // Trailing junk is rejected too.
    let mut long = good.clone();
    long.extend_from_slice(&[0, 1, 2, 3]);
    assert!(KernelKMeansModel::from_bytes(&long).is_err());
}

#[test]
fn every_truncation_point_errors() {
    let (_, model) = model_for(3, KernelFunction::Linear, 23);
    let good = model.to_bytes();
    for len in 0..good.len() {
        assert!(
            KernelKMeansModel::from_bytes(&good[..len]).is_err(),
            "prefix of {len}/{} bytes must fail cleanly",
            good.len()
        );
    }
}

#[test]
fn wrong_version_is_rejected_with_a_clear_error() {
    let (_, model) = model_for(4, KernelFunction::Linear, 29);
    let good = model.to_bytes();
    let hlen = u32::from_le_bytes([good[8], good[9], good[10], good[11]]) as usize;
    let header = std::str::from_utf8(&good[12..12 + hlen]).unwrap();
    let patched = header.replace("\"format_version\":2", "\"format_version\":7");
    assert_ne!(patched, header, "patch must hit the version field");
    let mut v7 = Vec::new();
    v7.extend_from_slice(&good[..8]);
    v7.extend_from_slice(&(patched.len() as u32).to_le_bytes());
    v7.extend_from_slice(patched.as_bytes());
    v7.extend_from_slice(&good[12 + hlen..]);
    let err = KernelKMeansModel::from_bytes(&v7).unwrap_err();
    // The version check fires before the checksum check on purpose, so a
    // future-format artifact says "upgrade" instead of "corrupt".
    let text = format!("{err}");
    assert!(text.contains("version 7") && text.contains("1..=2"), "{text}");
}

#[test]
fn artifact_kinds_do_not_cross_load() {
    let (ds, model) = model_for(4, KernelFunction::Gaussian { kappa: 5.0 }, 37);
    // A model artifact is not a checkpoint...
    let err = StreamingKernelKMeans::resume_bytes(&model.to_bytes()).unwrap_err();
    assert!(format!("{err}").contains("kind"), "{err}");
    // ...and a checkpoint is not a model.
    let mut rng = Rng::seeded(2);
    let mut stream = StreamingKernelKMeans::new(
        model.kernel,
        ds.d,
        3,
        16,
        20,
        LearningRate::Beta,
    );
    let mut rows = Vec::new();
    for _ in 0..16 {
        rows.extend_from_slice(ds.row(rng.below(ds.n)));
    }
    stream.partial_fit(&rows, &mut rng);
    let err = KernelKMeansModel::from_bytes(&stream.snapshot_bytes()).unwrap_err();
    assert!(format!("{err}").contains("kind"), "{err}");
}

#[test]
fn load_of_missing_file_is_an_error_with_the_path() {
    let path = tmp_path("definitely_missing");
    std::fs::remove_file(&path).ok();
    let err = KernelKMeansModel::load(&path).unwrap_err();
    assert!(format!("{err}").contains("mbkk_conformance"), "{err}");
}
