//! Quickstart: cluster non-trivial synthetic data with truncated mini-batch
//! kernel k-means in a few lines of library code.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::kernels::{Gram, KernelFunction};
use mbkk::kkmeans::{TruncatedConfig, TruncatedMiniBatchKernelKMeans};
use mbkk::metrics::{ari, nmi};
use mbkk::util::rng::Rng;

fn main() {
    let mut rng = Rng::seeded(7);

    // 1. Data: 4000 points in 8-d, five moderately-overlapping clusters.
    let ds = blobs(
        &SyntheticSpec::new(4000, 8, 5).with_std(0.9).with_separation(3.0),
        &mut rng,
    );
    println!("dataset: n={} d={} k=5", ds.n, ds.d);

    // 2. Kernel: Gaussian with the paper's κ heuristic (Wang et al. 2019).
    let kernel = KernelFunction::gaussian_with_heuristic_sigma(&ds, &mut rng);
    let gram = Gram::on_the_fly(&ds, kernel);
    println!("kernel: {:?}  (γ = {})", kernel, gram.gamma());

    // 3. Algorithm 2: truncated mini-batch kernel k-means, β learning rate,
    //    ε early stopping. Each iteration costs Õ(kb²) — independent of n.
    let cfg = TruncatedConfig {
        k: 5,
        batch_size: 256,
        tau: 100,
        max_iters: 200,
        epsilon: Some(1e-3),
        ..Default::default()
    };
    let result = TruncatedMiniBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);

    // 4. Evaluate against the generator's ground truth.
    let truth = ds.labels.as_ref().unwrap();
    println!("objective f_X = {:.4}", result.objective);
    println!(
        "ARI = {:.3}, NMI = {:.3}",
        ari(truth, &result.assignments),
        nmi(truth, &result.assignments)
    );
    println!(
        "iterations: {}{}",
        result.iterations,
        if result.converged { " (early-stopped)" } else { "" }
    );
    println!("\nphase timings:\n{}", result.profiler.report());
}
