//! The paper's motivating scenario: data that is **not linearly separable**.
//!
//! Plain (mini-batch) k-means cannot separate concentric rings — its
//! decision boundaries are hyperplanes. Kernel k-means over a graph kernel
//! separates them perfectly, and the truncated mini-batch version does it
//! at Õ(kb²) per iteration. This example runs all four on the same data and
//! prints the score table.
//!
//! ```bash
//! cargo run --release --example rings_vs_kmeans
//! ```

use mbkk::data::synthetic::rings;
use mbkk::kernels::graph::heat_kernel;
use mbkk::kkmeans::{
    FullBatchConfig, FullBatchKernelKMeans, TruncatedConfig, TruncatedMiniBatchKernelKMeans,
};
use mbkk::kmeans::{KMeans, KMeansConfig, MiniBatchKMeans, MiniBatchKMeansConfig};
use mbkk::metrics::ari;
use mbkk::util::rng::Rng;
use mbkk::util::timing::timed;

fn main() {
    let mut rng = Rng::seeded(3);
    let n = 1200;
    let ds = rings(n, 2, 3, 0.06, &mut rng);
    let truth = ds.labels.clone().unwrap();
    println!("dataset: 3 concentric rings, n={n} (not linearly separable)\n");

    // Heat kernel on the knn graph: affinity diffuses within each ring.
    let (gram, kernel_secs) = timed(|| heat_kernel(&ds, 10, 5000.0));
    println!("heat kernel built in {kernel_secs:.2}s (γ = {:.4})\n", gram.gamma());

    let mut report: Vec<(String, f64, f64)> = Vec::new();

    let (res, secs) = timed(|| {
        KMeans::new(KMeansConfig { k: 3, ..Default::default() }).fit(&ds, &mut Rng::seeded(1))
    });
    report.push(("k-means (Lloyd)".into(), ari(&truth, &res.assignments), secs));

    let (res, secs) = timed(|| {
        MiniBatchKMeans::new(MiniBatchKMeansConfig {
            k: 3,
            batch_size: 256,
            max_iters: 100,
            ..Default::default()
        })
        .fit(&ds, &mut Rng::seeded(1))
    });
    report.push(("mini-batch k-means".into(), ari(&truth, &res.assignments), secs));

    let (res, secs) = timed(|| {
        FullBatchKernelKMeans::new(FullBatchConfig { k: 3, max_iters: 100, ..Default::default() })
            .fit(&gram, &mut Rng::seeded(1))
    });
    report.push(("full-batch kernel k-means".into(), ari(&truth, &res.assignments), secs));

    let (res, secs) = timed(|| {
        TruncatedMiniBatchKernelKMeans::new(TruncatedConfig {
            k: 3,
            batch_size: 256,
            tau: 200,
            max_iters: 100,
            ..Default::default()
        })
        .fit(&gram, &mut Rng::seeded(1))
    });
    report.push((
        "β-trunc-mb kernel k-means (Alg 2)".into(),
        ari(&truth, &res.assignments),
        secs,
    ));

    println!("{:<36} {:>8} {:>10}", "algorithm", "ARI", "time");
    for (name, score, secs) in &report {
        println!("{name:<36} {score:>8.3} {:>9.2}s", secs);
    }
    println!();
    let kernel_best = report[2].1.max(report[3].1);
    let linear_best = report[0].1.max(report[1].1);
    assert!(
        kernel_best > 0.9 && linear_best < 0.5,
        "expected kernel methods ≫ linear methods on rings"
    );
    println!(
        "kernel methods (ARI ≥ {kernel_best:.2}) separate the rings; linear k-means (ARI ≤ {linear_best:.2}) cannot."
    );
}
