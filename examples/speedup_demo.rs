//! Speedup demo — the paper's abstract in one run.
//!
//! Clusters one dataset three ways (full-batch kernel k-means, Algorithm 1,
//! truncated Algorithm 2) and prints the time/quality trade-off, including
//! the XLA-backend variant when artifacts are available.
//!
//! ```bash
//! cargo run --release --example speedup_demo -- --scale 0.2
//! ```

use mbkk::coordinator::experiment::{run_with_gram, AlgoSpec, KernelSpec, RunSpec};
use mbkk::data::registry;
use mbkk::kkmeans::LearningRate;
use mbkk::util::cli::Args;
use mbkk::util::rng::Rng;

fn main() -> mbkk::util::error::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let dataset = args.get_or("dataset", "synth_pendigits");
    let scale = args.get_parse_or("scale", 0.6f64);
    let iters = args.get_parse_or("iters", 100usize);
    args.finish();

    let ds = registry::load(&dataset, scale, 7);
    let k = registry::default_k(&dataset);
    println!("dataset: {dataset} (n={}, d={}, k={k})", ds.n, ds.d);
    // The paper's 10-100x appears when n >> sqrt(k)*(tau+b): full batch pays
    // O(n^2) per iteration while Algorithm 2 pays O(k*(tau+b)^2) regardless
    // of n. At small --scale the crossover flips the comparison.

    let kernel = KernelSpec::Gaussian { multiplier: 1.0 };
    let mut rng = Rng::seeded(7);
    let (gram, kernel_secs) = kernel.build(&ds, &mut rng);
    println!("kernel matrix: {kernel_secs:.2}s (the paper's black bars)\n");

    let run = |name: &str, algo: AlgoSpec, tau: usize| {
        let spec = RunSpec {
            dataset: dataset.clone(),
            scale,
            kernel,
            algo,
            k,
            batch_size: 1024,
            schedule: mbkk::kkmeans::ScheduleSpec::Fixed,
            tau,
            max_iters: iters,
            epsilon: None,
            seed: 3,
        };
        let out = run_with_gram(&spec, &ds, Some(&gram), kernel_secs);
        println!(
            "{name:<28} {:>8.2}s   ARI {:.3}   NMI {:.3}   obj {:.5}",
            out.cluster_secs, out.ari, out.nmi, out.objective
        );
        out
    };

    println!("{:<28} {:>9}   {:<9} {:<9} {:<9}", "algorithm", "time", "ARI", "NMI", "objective");
    let full = run("full-batch kernel k-means", AlgoSpec::FullKkm, usize::MAX);
    let alg1 = run(
        "mini-batch (Alg 1, β)",
        AlgoSpec::MbKkm(LearningRate::Beta),
        usize::MAX,
    );
    let alg2 = run(
        "truncated (Alg 2, β, τ=200)",
        AlgoSpec::TruncKkm(LearningRate::Beta),
        200,
    );

    println!(
        "\nspeedup vs full batch: alg1 {:.1}x, alg2 {:.1}x (paper: 10-100x)",
        full.cluster_secs / alg1.cluster_secs.max(1e-9),
        full.cluster_secs / alg2.cluster_secs.max(1e-9),
    );
    println!(
        "quality gap (ARI): alg1 {:+.3}, alg2 {:+.3} (paper: minimal loss)",
        alg1.ari - full.ari,
        alg2.ari - full.ari
    );
    Ok(())
}
