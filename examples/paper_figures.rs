//! End-to-end driver: regenerate the paper's full evaluation on the proxy
//! datasets and write every figure's data + Table 1 under `results/`.
//!
//! This is the repository's end-to-end validation run (EXPERIMENTS.md):
//! it exercises dataset generation, all three kernels (including the heat
//! kernel's matrix exponential), k-means++ init, all five algorithms with
//! both learning rates, the sliding-window state, metrics, aggregation,
//! and the report writers — i.e. every layer of the system composed.
//!
//! ```bash
//! cargo run --release --example paper_figures                 # reduced grid
//! cargo run --release --example paper_figures -- --full       # paper grid
//! cargo run --release --example paper_figures -- --scale 0.1 --repeats 2
//! ```

use mbkk::coordinator::figures::{self, FigureOptions};
use mbkk::util::cli::Args;
use mbkk::util::timing::Stopwatch;
use std::path::Path;

fn main() -> mbkk::util::error::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let opts = FigureOptions {
        scale: args.get_parse_or("scale", 0.15f64),
        repeats: args.get_parse_or("repeats", 3usize),
        max_iters: args.get_parse_or("iters", 200usize),
        quick: !args.flag("full"),
        seed: args.get_parse_or("seed", 7u64),
    };
    let out = args.get_or("out", "results");
    args.finish();
    let out_dir = Path::new(&out);

    println!(
        "== paper figures: scale={} repeats={} iters={} grid={} ==",
        opts.scale,
        opts.repeats,
        opts.max_iters,
        if opts.quick { "reduced" } else { "full (paper)" }
    );
    let sw = Stopwatch::start();

    // Table 1 first (cheap) …
    let md = figures::run_gamma_table(opts.scale, opts.seed, Some(out_dir))?;
    println!("\nTable 1 (γ):\n{md}");

    // … then every figure.
    let mut total_rows = 0;
    for id in figures::figure_ids() {
        let rows = figures::run_figure(id, &opts, Some(out_dir))?;
        total_rows += rows.len();

        // Spot-check the paper's qualitative claims on the main figure.
        if id == 1 {
            check_figure1(&rows);
        }
    }
    println!(
        "\nwrote {total_rows} aggregated rows to {}/ in {:.1}s",
        out_dir.display(),
        sw.secs()
    );
    Ok(())
}

/// Figure 1 sanity: (a) truncated mini-batch quality ≈ full batch,
/// (b) kernel versions ≥ non-kernel versions on these datasets,
/// (c) mini-batch clustering time ≪ full-batch clustering time.
fn check_figure1(rows: &[mbkk::coordinator::report::Row]) {
    for dataset in ["synth_mnist", "synth_har", "synth_letters", "synth_pendigits"] {
        let get = |algo: &str| {
            rows.iter()
                .find(|r| r.dataset == dataset && r.algo == algo)
                .unwrap_or_else(|| panic!("missing {algo} row for {dataset}"))
        };
        let full = get("full-kkm");
        let trunc = get("btrunc-kkm");
        let mbkm = get("bmb-km");
        println!(
            "[check fig1] {dataset}: full ARI {:.3} ({:.1}s) | btrunc ARI {:.3} ({:.1}s) | bmb-km ARI {:.3}",
            full.ari.mean, full.cluster_secs.mean, trunc.ari.mean,
            trunc.cluster_secs.mean, mbkm.ari.mean,
        );
        if full.cluster_secs.mean > 0.5 {
            let speedup = full.cluster_secs.mean / trunc.cluster_secs.mean.max(1e-9);
            println!("[check fig1] {dataset}: speedup full/trunc = {speedup:.1}x");
        }
    }
}
